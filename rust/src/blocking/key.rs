//! Key (equality/range) blocking on an attribute.
//!
//! The paper's running configuration: block products by product type or
//! by manufacturer.  Entities with a missing key value go to *misc*.

use super::Blocks;
use crate::features::normalize;
use crate::model::Dataset;

/// Block by exact (normalized) attribute value.
pub fn block(dataset: &Dataset, attribute: &str) -> Blocks {
    let mut blocks = Blocks::new();
    for e in &dataset.entities {
        match e.get(&dataset.schema, attribute) {
            Some(v) if !v.trim().is_empty() => {
                blocks.add(&normalize(v), e.id);
            }
            _ => blocks.add_misc(e.id),
        }
    }
    blocks
}

/// Range blocking on a numeric attribute: bucket by `value / bucket_width`.
/// (e.g. partition publications by year, products by price band.)
pub fn block_numeric_range(
    dataset: &Dataset,
    attribute: &str,
    bucket_width: f64,
) -> Blocks {
    assert!(bucket_width > 0.0);
    let mut blocks = Blocks::new();
    for e in &dataset.entities {
        let parsed = e
            .get(&dataset.schema, attribute)
            .and_then(|v| v.trim().parse::<f64>().ok());
        match parsed {
            Some(x) if x.is_finite() => {
                let bucket = (x / bucket_width).floor() as i64;
                blocks.add(&format!("{attribute}:{bucket}"), e.id);
            }
            _ => blocks.add_misc(e.id),
        }
    }
    blocks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::model::{
        Dataset, Entity, EntityId, Schema, ATTR_PRODUCT_TYPE, ATTR_TITLE,
    };

    fn dataset_with_types(types: &[Option<&str>]) -> Dataset {
        let schema = Schema::new(vec![ATTR_TITLE, ATTR_PRODUCT_TYPE, "price"]);
        let mut ds = Dataset::new(schema.clone());
        for (i, t) in types.iter().enumerate() {
            let mut e = Entity::new(EntityId(i as u32), &schema);
            e.set(&schema, ATTR_TITLE, format!("product {i}"));
            if let Some(t) = t {
                e.set(&schema, ATTR_PRODUCT_TYPE, t.to_string());
            }
            ds.push(e);
        }
        ds
    }

    #[test]
    fn groups_by_value_and_collects_misc() {
        let ds = dataset_with_types(&[
            Some("SSD"),
            Some("ssd"), // case-insensitive via normalize
            Some("NAS"),
            None,
            Some("  "), // blank counts as missing
        ]);
        let b = block(&ds, ATTR_PRODUCT_TYPE);
        assert_eq!(b.n_blocks(), 2);
        assert_eq!(b.get("ssd").unwrap().len(), 2);
        assert_eq!(b.get("nas").unwrap().len(), 1);
        assert_eq!(b.misc().len(), 2);
        b.assert_disjoint_cover(5);
    }

    #[test]
    fn covers_generated_dataset() {
        let g = GeneratorConfig::tiny().generate();
        let b = block(&g.dataset, ATTR_PRODUCT_TYPE);
        b.assert_disjoint_cover(g.dataset.len());
        assert!(b.n_blocks() > 3);
        assert!(!b.misc().is_empty(), "generator injects missing types");
    }

    #[test]
    fn numeric_range_buckets() {
        let schema = Schema::new(vec![ATTR_TITLE, ATTR_PRODUCT_TYPE, "price"]);
        let mut ds = Dataset::new(schema.clone());
        for (i, p) in ["9.99", "19.99", "15.00", "x", ""].iter().enumerate() {
            let mut e = Entity::new(EntityId(i as u32), &schema);
            e.set(&schema, "price", p.to_string());
            ds.push(e);
        }
        let b = block_numeric_range(&ds, "price", 10.0);
        assert_eq!(b.get("price:0").unwrap().len(), 1); // 9.99
        assert_eq!(b.get("price:1").unwrap().len(), 2); // 19.99, 15.00
        assert_eq!(b.misc().len(), 2); // unparsable
        b.assert_disjoint_cover(5);
    }

    #[test]
    fn duplicates_land_in_same_block() {
        let g = GeneratorConfig::tiny().with_seed(5).generate();
        let b = block(&g.dataset, ATTR_PRODUCT_TYPE);
        let schema = &g.dataset.schema;
        for &(x, y) in g.truth.iter().take(50) {
            let (ex, ey) = (
                g.dataset.get(x).unwrap(),
                g.dataset.get(y).unwrap(),
            );
            if let (Some(tx), Some(ty)) =
                (ex.product_type(schema), ey.product_type(schema))
            {
                assert_eq!(tx, ty);
                let blk = b.get(&crate::features::normalize(tx)).unwrap();
                assert!(blk.contains(&x) && blk.contains(&y));
            }
        }
    }
}
