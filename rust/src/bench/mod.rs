//! In-tree micro-benchmark harness (std-only replacement for criterion —
//! unavailable offline).
//!
//! `cargo bench` targets (`rust/benches/*.rs`, `harness = false`) use
//! [`Bencher`] for timed kernels and the free functions here to render
//! the per-figure/table experiment reports.
//!
//! When the environment variable `PEM_BENCH_JSON` names a directory,
//! benches additionally write a schema'd `BENCH_<name>.json` snapshot
//! there (see [`write_json_snapshot`]) — the machine-readable
//! trajectory `scripts/bench_snapshot.sh` collects and CI archives.

use crate::obs::registry::json_string;
use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Measurement configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop once this much time has been spent measuring.
    pub time_budget: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 50,
            time_budget: Duration::from_secs(3),
        }
    }
}

impl BenchConfig {
    /// Quick mode for CI-ish runs (env `PEM_BENCH_QUICK=1`).
    pub fn from_env() -> BenchConfig {
        if std::env::var("PEM_BENCH_QUICK").is_ok_and(|v| v != "0") {
            BenchConfig {
                warmup_iters: 1,
                min_iters: 2,
                max_iters: 5,
                time_budget: Duration::from_millis(300),
            }
        } else {
            BenchConfig::default()
        }
    }
}

/// One measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration timings in nanoseconds.
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples_ns)
    }

    pub fn report_line(&self) -> String {
        let s = self.summary();
        format!(
            "{:<40} median {:>12}  mad {:>10}  n={}",
            self.name,
            crate::util::fmt_nanos(s.median as u64),
            crate::util::fmt_nanos(s.mad as u64),
            s.n
        )
    }
}

/// Timed-closure bench runner.
pub struct Bencher {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(BenchConfig::from_env())
    }
}

impl Bencher {
    pub fn new(cfg: BenchConfig) -> Bencher {
        Bencher {
            cfg,
            results: Vec::new(),
        }
    }

    /// Measure `f` (which must fully perform the work per call).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.cfg.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let started = Instant::now();
        while samples.len() < self.cfg.min_iters
            || (samples.len() < self.cfg.max_iters
                && started.elapsed() < self.cfg.time_budget)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            samples_ns: samples,
        };
        println!("{}", result.report_line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write everything measured so far as the `bench` snapshot (see
    /// [`write_json_snapshot`]); a no-op unless `PEM_BENCH_JSON` is
    /// set.
    pub fn write_snapshot(&self, bench: &str) -> std::io::Result<()> {
        write_json_snapshot(bench, &self.results)
    }
}

/// Schema identifier written into every bench snapshot file.
pub const SNAPSHOT_SCHEMA: &str = "pem-bench-snapshot/1";

/// A single-sample [`BenchResult`]: figure benches measure one
/// makespan per configuration rather than iterating a closure, and
/// record each as a point.
pub fn point(name: impl Into<String>, value_ns: u64) -> BenchResult {
    BenchResult {
        name: name.into(),
        samples_ns: vec![value_ns as f64],
    }
}

/// Write `BENCH_<bench>.json` into the directory named by the
/// `PEM_BENCH_JSON` environment variable (created if missing);
/// returns without writing when the variable is unset.
///
/// The file is one JSON object: `schema`, `bench`, `quick` (whether
/// `PEM_BENCH_QUICK` reduced the workload), `created_unix`,
/// `provenance` (free-form `PEM_BENCH_PROVENANCE`, default
/// `"unrecorded"` — committed snapshots must say what hardware
/// produced them), and `results`, an array of per-measurement summary
/// stats in nanoseconds.
pub fn write_json_snapshot(
    bench: &str,
    results: &[BenchResult],
) -> std::io::Result<()> {
    let Some(dir) = std::env::var_os("PEM_BENCH_JSON") else {
        return Ok(());
    };
    let dir = std::path::PathBuf::from(dir);
    std::fs::create_dir_all(&dir)?;
    let quick =
        std::env::var("PEM_BENCH_QUICK").is_ok_and(|v| v != "0");
    let provenance = std::env::var("PEM_BENCH_PROVENANCE")
        .unwrap_or_else(|_| "unrecorded".to_string());
    let created = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut body = String::with_capacity(256 + results.len() * 160);
    body.push_str("{\n");
    body.push_str(&format!(
        "  \"schema\": {},\n",
        json_string(SNAPSHOT_SCHEMA)
    ));
    body.push_str(&format!("  \"bench\": {},\n", json_string(bench)));
    body.push_str(&format!("  \"quick\": {quick},\n"));
    body.push_str(&format!("  \"created_unix\": {created},\n"));
    body.push_str(&format!(
        "  \"provenance\": {},\n",
        json_string(&provenance)
    ));
    body.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        let s = r.summary();
        body.push_str(&format!(
            "    {{\"name\": {}, \"n\": {}, \"mean_ns\": {:.1}, \
             \"median_ns\": {:.1}, \"min_ns\": {:.1}, \"max_ns\": \
             {:.1}, \"mad_ns\": {:.1}, \"stddev_ns\": {:.1}}}{}\n",
            json_string(&r.name),
            s.n,
            s.mean,
            s.median,
            s.min,
            s.max,
            s.mad,
            s.stddev,
            if i + 1 < results.len() { "," } else { "" },
        ));
    }
    body.push_str("  ]\n}\n");
    let path = dir.join(format!("BENCH_{bench}.json"));
    std::fs::write(&path, body)?;
    println!("wrote bench snapshot to {}", path.display());
    Ok(())
}

/// Render a report header for a figure/table reproduction bench.
pub fn report_header(experiment: &str, paper_claim: &str) {
    println!("\n=== {experiment} ===");
    println!("paper: {paper_claim}");
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_collects_samples() {
        let mut b = Bencher::new(BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            time_budget: Duration::from_millis(50),
        });
        let mut count = 0u64;
        let r = b.bench("noop", || {
            count += 1;
            std::hint::black_box(count);
        });
        assert!(r.samples_ns.len() >= 3);
        assert!(count >= 4); // warmup + samples
        let s = r.summary();
        assert!(s.median >= 0.0);
    }

    #[test]
    fn respects_max_iters() {
        let mut b = Bencher::new(BenchConfig {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 3,
            time_budget: Duration::from_secs(60),
        });
        let r = b.bench("capped", || {
            std::thread::sleep(Duration::from_micros(10))
        });
        assert!(r.samples_ns.len() <= 3);
    }

    #[test]
    fn json_snapshot_written_when_env_set() {
        let dir = std::env::temp_dir()
            .join(format!("pem_bench_snap_{}", std::process::id()));
        std::env::set_var("PEM_BENCH_JSON", &dir);
        let r = point("cell/a", 1500);
        write_json_snapshot("unit_test", &[r]).unwrap();
        std::env::remove_var("PEM_BENCH_JSON");
        let path = dir.join("BENCH_unit_test.json");
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"schema\": \"pem-bench-snapshot/1\""));
        assert!(body.contains("\"bench\": \"unit_test\""));
        assert!(body.contains("\"name\": \"cell/a\""));
        assert!(body.contains("\"median_ns\": 1500.0"));
        assert!(body.contains("\"provenance\""));
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn quick_env_config() {
        // from_env without the var → default
        std::env::remove_var("PEM_BENCH_QUICK");
        let c = BenchConfig::from_env();
        assert_eq!(c.min_iters, BenchConfig::default().min_iters);
    }
}
