//! In-tree micro-benchmark harness (std-only replacement for criterion —
//! unavailable offline).
//!
//! `cargo bench` targets (`rust/benches/*.rs`, `harness = false`) use
//! [`Bencher`] for timed kernels and the free functions here to render
//! the per-figure/table experiment reports.

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Measurement configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop once this much time has been spent measuring.
    pub time_budget: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 50,
            time_budget: Duration::from_secs(3),
        }
    }
}

impl BenchConfig {
    /// Quick mode for CI-ish runs (env `PEM_BENCH_QUICK=1`).
    pub fn from_env() -> BenchConfig {
        if std::env::var("PEM_BENCH_QUICK").is_ok_and(|v| v != "0") {
            BenchConfig {
                warmup_iters: 1,
                min_iters: 2,
                max_iters: 5,
                time_budget: Duration::from_millis(300),
            }
        } else {
            BenchConfig::default()
        }
    }
}

/// One measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration timings in nanoseconds.
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples_ns)
    }

    pub fn report_line(&self) -> String {
        let s = self.summary();
        format!(
            "{:<40} median {:>12}  mad {:>10}  n={}",
            self.name,
            crate::util::fmt_nanos(s.median as u64),
            crate::util::fmt_nanos(s.mad as u64),
            s.n
        )
    }
}

/// Timed-closure bench runner.
pub struct Bencher {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(BenchConfig::from_env())
    }
}

impl Bencher {
    pub fn new(cfg: BenchConfig) -> Bencher {
        Bencher {
            cfg,
            results: Vec::new(),
        }
    }

    /// Measure `f` (which must fully perform the work per call).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.cfg.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let started = Instant::now();
        while samples.len() < self.cfg.min_iters
            || (samples.len() < self.cfg.max_iters
                && started.elapsed() < self.cfg.time_budget)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            samples_ns: samples,
        };
        println!("{}", result.report_line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Render a report header for a figure/table reproduction bench.
pub fn report_header(experiment: &str, paper_claim: &str) {
    println!("\n=== {experiment} ===");
    println!("paper: {paper_claim}");
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_collects_samples() {
        let mut b = Bencher::new(BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            time_budget: Duration::from_millis(50),
        });
        let mut count = 0u64;
        let r = b.bench("noop", || {
            count += 1;
            std::hint::black_box(count);
        });
        assert!(r.samples_ns.len() >= 3);
        assert!(count >= 4); // warmup + samples
        let s = r.summary();
        assert!(s.median >= 0.0);
    }

    #[test]
    fn respects_max_iters() {
        let mut b = Bencher::new(BenchConfig {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 3,
            time_budget: Duration::from_secs(60),
        });
        let r = b.bench("capped", || {
            std::thread::sleep(Duration::from_micros(10))
        });
        assert!(r.samples_ns.len() <= 3);
    }

    #[test]
    fn quick_env_config() {
        // from_env without the var → default
        std::env::remove_var("PEM_BENCH_QUICK");
        let c = BenchConfig::from_env();
        assert_eq!(c.min_iters, BenchConfig::default().min_iters);
    }
}
