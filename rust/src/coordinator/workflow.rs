//! Legacy workflow configuration — a thin shim over the plan/execute
//! split.
//!
//! **Deprecated in favor of the [`super::Workflow`] builder** (kept for
//! one release so downstream code can migrate; see
//! `docs/MIGRATION.md`).  [`WorkflowConfig`] closed the workflow over
//! two enums — [`PartitioningChoice`] for the partitioning stage and
//! [`EngineChoice`] for execution — plus a flat bag of engine-specific
//! knobs.  The open API replaces the enums with the
//! [`PartitionStrategy`](crate::partition::PartitionStrategy) and
//! [`ExecutionBackend`](crate::engine::backend::ExecutionBackend)
//! traits and moves the knobs into per-backend option structs
//! ([`crate::engine::backend::SimOptions`],
//! [`crate::engine::backend::DistOptions`]).  [`run_workflow`] and
//! [`build_partitions`] now just translate a config into the builder
//! and delegate — both paths are property-tested result-identical in
//! `tests/plan_determinism.rs`.

use crate::blocking::BlockingMethod;
use crate::cluster::ComputingEnv;
use crate::engine::backend::{
    Dist, DistOptions, ExecutionBackend, Sim, SimOptions, Threads,
};
use crate::engine::CostParams;
use crate::matching::{MatchStrategy, StrategyKind};
use crate::net::CostModel;
use crate::obs::Stopwatch;
use crate::partition::{
    PartitionSet, PartitionStrategy, PlanContext,
};
use anyhow::Result;

pub use super::builder::RunOutcome;
pub use crate::partition::strategy::{default_max_size, default_min_size};

/// Which partitioning strategy the workflow applies.
///
/// Legacy closed enum; new code passes a
/// [`PartitionStrategy`](crate::partition::PartitionStrategy) impl to
/// [`super::Workflow::strategy`] instead (which is how the
/// sorted-neighborhood strategy is available there but not here).
#[derive(Clone, Debug)]
pub enum PartitioningChoice {
    /// §3.1 — Cartesian product with equally-sized partitions.
    SizeBased {
        /// Maximum partition size; `None` derives m from the memory
        /// model.
        max_size: Option<usize>,
    },
    /// §3.2 — blocking followed by partition tuning.
    BlockingBased {
        /// Blocking method (e.g. by product type or manufacturer).
        method: BlockingMethod,
        /// Maximum partition size; `None` derives m from the memory
        /// model.
        max_size: Option<usize>,
        /// Minimum partition size for aggregating small blocks.
        min_size: usize,
    },
}

impl PartitioningChoice {
    /// The equivalent open-API strategy.
    pub fn to_strategy(&self) -> Box<dyn PartitionStrategy> {
        match self {
            PartitioningChoice::SizeBased { max_size } => {
                Box::new(crate::partition::SizeBased {
                    max_size: *max_size,
                })
            }
            PartitioningChoice::BlockingBased {
                method,
                max_size,
                min_size,
            } => Box::new(crate::partition::BlockingBased {
                method: method.clone(),
                max_size: *max_size,
                min_size: Some(*min_size),
            }),
        }
    }
}

/// Which engine executes the match tasks.
///
/// Legacy closed enum; new code passes an
/// [`ExecutionBackend`](crate::engine::backend::ExecutionBackend) impl
/// to [`super::Workflow::backend`] instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineChoice {
    /// Real OS threads; real matching; wall-clock metrics.
    Threads,
    /// Virtual-time simulation with calibrated costs; no matching
    /// performed (metrics only) unless `execute_in_sim` is set.
    Simulated,
    /// Real services over localhost TCP ([`crate::engine::dist`]):
    /// workflow + data services, `ce.nodes` match-service nodes, the
    /// [`crate::rpc`] wire protocol in between; wall-clock metrics and
    /// actual socket-byte traffic accounting.
    Distributed,
}

/// Full workflow configuration (legacy shim; see module docs).
#[derive(Clone, Debug)]
pub struct WorkflowConfig {
    /// Match strategy (WAM or LRM) with its decision threshold.
    pub strategy: MatchStrategy,
    /// Partitioning strategy (§3.1 size-based or §3.2 blocking-based).
    pub partitioning: PartitioningChoice,
    /// Which engine executes the match tasks.
    pub engine: EngineChoice,
    /// Partition-cache capacity per match service (`c`; 0 = disabled).
    pub cache_capacity: usize,
    /// Task-assignment policy (FIFO or affinity).
    pub policy: crate::coordinator::Policy,
    /// Distributed engine: total data-plane servers (1 = just the
    /// primary; N > 1 adds N−1 synced replicas and fetch failover).
    pub data_replicas: usize,
    /// Distributed engine: tasks pulled per control round trip
    /// (batched assignment; 1 = classic per-task pull).
    pub batch: usize,
    /// Distributed engine: host the services bind (default loopback).
    pub bind: String,
    /// Control-plane cost model (workflow-service RMI).
    pub net: CostModel,
    /// Data-plane cost model (data-service partition fetches).
    pub data_net: CostModel,
    /// Simulated engine: also execute the tasks to produce real
    /// correspondences (small workloads only).
    pub execute_in_sim: bool,
    /// Simulated engine: calibrate per-pair cost by really matching a
    /// sample (otherwise use the strategy's default constants).
    pub calibrate: bool,
    /// Simulated engine: use these cost params verbatim (skips
    /// calibration).  Sweeps MUST pin the cost once and reuse it —
    /// re-calibrating per configuration injects real-timer noise into
    /// virtual-time ratios.
    pub cost_override: Option<CostParams>,
    /// Simulated node failures (virtual ns, node index).
    pub failures: Vec<(u64, usize)>,
}

impl WorkflowConfig {
    /// Blocking-based partitioning by product type, simulated engine —
    /// the paper's primary configuration.
    pub fn blocking_based(kind: StrategyKind) -> WorkflowConfig {
        WorkflowConfig {
            strategy: MatchStrategy::new(kind),
            partitioning: PartitioningChoice::BlockingBased {
                method: BlockingMethod::product_type(),
                max_size: None,
                min_size: default_min_size(kind),
            },
            engine: EngineChoice::Simulated,
            cache_capacity: 0,
            policy: crate::coordinator::Policy::Affinity,
            data_replicas: 1,
            batch: 1,
            bind: "127.0.0.1".to_string(),
            net: CostModel::lan(),
            data_net: CostModel::dbms(),
            execute_in_sim: false,
            calibrate: true,
            cost_override: None,
            failures: Vec::new(),
        }
    }

    /// Size-based (Cartesian) partitioning, simulated engine.
    pub fn size_based(kind: StrategyKind) -> WorkflowConfig {
        WorkflowConfig {
            partitioning: PartitioningChoice::SizeBased { max_size: None },
            ..WorkflowConfig::blocking_based(kind)
        }
    }

    /// Select the execution engine (builder style).
    pub fn with_engine(mut self, engine: EngineChoice) -> Self {
        self.engine = engine;
        self
    }

    /// Set the per-service partition-cache capacity (builder style).
    pub fn with_cache(mut self, c: usize) -> Self {
        self.cache_capacity = c;
        self
    }

    /// Pin simulator cost params verbatim (builder style).
    pub fn with_cost(mut self, cost: CostParams) -> Self {
        self.cost_override = Some(cost);
        self
    }

    /// Distributed engine: run this many data-plane servers (builder
    /// style; clamped to ≥ 1 at run time).
    pub fn with_data_replicas(mut self, n: usize) -> Self {
        self.data_replicas = n;
        self
    }

    /// Distributed engine: pull this many tasks per control round
    /// trip (builder style; clamped to ≥ 1 at run time).
    pub fn with_batch(mut self, k: usize) -> Self {
        self.batch = k;
        self
    }

    /// The equivalent open-API backend for this config's engine choice
    /// and flat knobs.
    pub fn to_backend(&self) -> Box<dyn ExecutionBackend> {
        match self.engine {
            EngineChoice::Threads => Box::new(Threads),
            EngineChoice::Distributed => Box::new(Dist(DistOptions {
                replicas: self.data_replicas.max(1),
                batch: self.batch.max(1),
                bind: self.bind.clone(),
                ..DistOptions::default()
            })),
            EngineChoice::Simulated => Box::new(Sim(SimOptions {
                net: self.net,
                data_net: self.data_net,
                execute: self.execute_in_sim,
                calibrate: self.calibrate,
                cost_override: self.cost_override,
                failures: self.failures.clone(),
            })),
        }
    }
}

/// Workflow outcome — alias of the builder's [`RunOutcome`] so legacy
/// call sites keep compiling.
pub type WorkflowOutcome = RunOutcome;

/// Build the partition set for a workflow (pre-processing half).
/// Legacy shim over [`PartitionStrategy::partition`].
pub fn build_partitions(
    dataset: &crate::model::Dataset,
    cfg: &WorkflowConfig,
    ce: &ComputingEnv,
) -> Result<PartitionSet> {
    let ctx = PlanContext {
        ce,
        match_kind: cfg.strategy.kind,
    };
    cfg.partitioning.to_strategy().partition(dataset, &ctx)
}

/// Run a complete match workflow.  Legacy shim: translates the config
/// into the [`super::Workflow`] builder and delegates.
pub fn run_workflow(
    dataset: &crate::model::Dataset,
    cfg: &WorkflowConfig,
    ce: &ComputingEnv,
) -> Result<WorkflowOutcome> {
    let started = Stopwatch::start();
    let mut out = super::Workflow::for_dataset(dataset)
        .match_strategy(cfg.strategy)
        .strategy_boxed(cfg.partitioning.to_strategy())
        .backend_boxed(cfg.to_backend())
        .env(*ce)
        .cache(cfg.cache_capacity)
        .policy(cfg.policy)
        .plan()?
        .execute()?;
    out.elapsed = started.elapsed();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::partition::max_partition_size;

    fn tiny_ce() -> ComputingEnv {
        ComputingEnv::new(1, 2, crate::util::GIB)
    }

    #[test]
    fn size_based_thread_workflow_finds_duplicates() {
        let data = GeneratorConfig::tiny().with_seed(21).generate();
        let cfg = WorkflowConfig::size_based(StrategyKind::Wam)
            .with_engine(EngineChoice::Threads);
        let out = run_workflow(&data, &cfg, &tiny_ce()).unwrap();
        assert!(out.n_tasks >= out.n_partitions);
        let q = out.result.quality(&data.truth);
        assert!(q.recall > 0.8, "recall {}", q.recall);
        assert!(q.precision > 0.5, "precision {}", q.precision);
    }

    #[test]
    fn blocking_based_reduces_comparisons() {
        let data = GeneratorConfig::tiny().with_entities(1200).generate();
        let ce = tiny_ce();
        let size = run_workflow(
            &data,
            &WorkflowConfig::size_based(StrategyKind::Wam)
                .with_engine(EngineChoice::Threads),
            &ce,
        )
        .unwrap();
        // tuning bounds sized to the dataset: ~37 product types over
        // 1,200 entities → blocks of ~10-150; max 200 keeps aggregates
        // small enough that blocking actually prunes the search space
        let mut bcfg = WorkflowConfig::blocking_based(StrategyKind::Wam)
            .with_engine(EngineChoice::Threads);
        if let PartitioningChoice::BlockingBased {
            max_size, min_size, ..
        } = &mut bcfg.partitioning
        {
            *max_size = Some(200);
            *min_size = 40;
        }
        let block = run_workflow(&data, &bcfg, &ce).unwrap();
        assert!(
            block.metrics.comparisons < size.metrics.comparisons / 2,
            "blocking {} vs cartesian {}",
            block.metrics.comparisons,
            size.metrics.comparisons
        );
        // and loses almost no recall on same-type duplicates (misc block
        // handling keeps entities with missing product type matchable)
        let qb = block.result.quality(&data.truth);
        let qs = size.result.quality(&data.truth);
        assert!(
            qb.recall >= qs.recall - 0.05,
            "blocking recall {} vs {}",
            qb.recall,
            qs.recall
        );
    }

    #[test]
    fn simulated_workflow_produces_metrics_without_matching() {
        let data = GeneratorConfig::tiny().generate();
        let mut cfg = WorkflowConfig::blocking_based(StrategyKind::Lrm);
        cfg.calibrate = false; // keep test fast & deterministic
        let out = run_workflow(&data, &cfg, &ComputingEnv::paper_testbed(4))
            .unwrap();
        assert!(out.metrics.makespan_ns > 0);
        assert_eq!(out.result.len(), 0, "sim without execute");
        assert!(out.cost.is_some());
    }

    #[test]
    fn sim_execute_equals_threads_result() {
        let data = GeneratorConfig::tiny().with_seed(9).generate();
        let base = WorkflowConfig::blocking_based(StrategyKind::Wam);
        let t = run_workflow(
            &data,
            &base.clone().with_engine(EngineChoice::Threads),
            &tiny_ce(),
        )
        .unwrap();
        let mut sim_cfg = base;
        sim_cfg.execute_in_sim = true;
        sim_cfg.calibrate = false;
        let s =
            run_workflow(&data, &sim_cfg, &ComputingEnv::paper_testbed(2))
                .unwrap();
        assert_eq!(t.result.len(), s.result.len());
        for c in t.result.iter() {
            assert!(s.result.contains(c.e1, c.e2));
        }
    }

    #[test]
    fn memory_model_caps_partition_size() {
        let data = GeneratorConfig::tiny().generate();
        // tiny memory → small partitions even though default is 500
        let ce = ComputingEnv::new(1, 4, 64 * crate::util::MIB);
        let cfg = WorkflowConfig::size_based(StrategyKind::Lrm);
        let parts = build_partitions(&data, &cfg, &ce).unwrap();
        let cap = max_partition_size(&ce, StrategyKind::Lrm);
        assert!(parts.max_size() <= cap);
        assert!(cap < 500, "cap {cap} should bind");
    }

    #[test]
    fn invalid_min_size_rejected() {
        let data = GeneratorConfig::tiny().generate();
        let mut cfg = WorkflowConfig::blocking_based(StrategyKind::Wam);
        if let PartitioningChoice::BlockingBased { min_size, .. } =
            &mut cfg.partitioning
        {
            *min_size = 10_000;
        }
        assert!(run_workflow(&data, &cfg, &tiny_ce()).is_err());
    }
}
