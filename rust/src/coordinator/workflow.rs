//! End-to-end match workflows: Figure 1 of the paper.
//!
//! ```text
//! input ─▶ [blocking]? ─▶ partitioning (size-based | blocking-based
//!        with partition tuning) ─▶ match task generation ─▶ parallel
//!        execution (threads | virtual-time sim) ─▶ merged match result
//! ```

use crate::blocking::BlockingMethod;
use crate::cluster::ComputingEnv;
use crate::engine::{calibrate, dist, sim, threads, CostParams};
use crate::matching::{MatchStrategy, StrategyKind};
use crate::metrics::RunMetrics;
use crate::model::{Dataset, EntityId, MatchResult};
use crate::net::CostModel;
use crate::partition::{
    generate_tasks, max_partition_size, partition_size_based, tune,
    MatchTask, PartitionSet, TuningConfig,
};
use crate::store::DataService;
use crate::worker::RustExecutor;
use anyhow::{bail, Result};
use std::time::Instant;

/// Which partitioning strategy the workflow applies.
#[derive(Clone, Debug)]
pub enum PartitioningChoice {
    /// §3.1 — Cartesian product with equally-sized partitions.
    SizeBased {
        /// Maximum partition size; `None` derives m from the memory
        /// model.
        max_size: Option<usize>,
    },
    /// §3.2 — blocking followed by partition tuning.
    BlockingBased {
        /// Blocking method (e.g. by product type or manufacturer).
        method: BlockingMethod,
        /// Maximum partition size; `None` derives m from the memory
        /// model.
        max_size: Option<usize>,
        /// Minimum partition size for aggregating small blocks.
        min_size: usize,
    },
}

/// Which engine executes the match tasks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineChoice {
    /// Real OS threads; real matching; wall-clock metrics.
    Threads,
    /// Virtual-time simulation with calibrated costs; no matching
    /// performed (metrics only) unless `execute_in_sim` is set.
    Simulated,
    /// Real services over localhost TCP ([`crate::engine::dist`]):
    /// workflow + data services, `ce.nodes` match-service nodes, the
    /// [`crate::rpc`] wire protocol in between; wall-clock metrics and
    /// actual socket-byte traffic accounting.
    Distributed,
}

/// Full workflow configuration.
#[derive(Clone, Debug)]
pub struct WorkflowConfig {
    /// Match strategy (WAM or LRM) with its decision threshold.
    pub strategy: MatchStrategy,
    /// Partitioning strategy (§3.1 size-based or §3.2 blocking-based).
    pub partitioning: PartitioningChoice,
    /// Which engine executes the match tasks.
    pub engine: EngineChoice,
    /// Partition-cache capacity per match service (`c`; 0 = disabled).
    pub cache_capacity: usize,
    /// Task-assignment policy (FIFO or affinity).
    pub policy: crate::coordinator::Policy,
    /// Distributed engine: total data-plane servers (1 = just the
    /// primary; N > 1 adds N−1 synced replicas and fetch failover).
    pub data_replicas: usize,
    /// Distributed engine: tasks pulled per control round trip
    /// (protocol v3 batched assignment; 1 = classic per-task pull).
    pub batch: usize,
    /// Distributed engine: host the services bind (default loopback;
    /// the ROADMAP fix for the unconditional `0.0.0.0` binds).
    pub bind: String,
    /// Control-plane cost model (workflow-service RMI).
    pub net: CostModel,
    /// Data-plane cost model (data-service partition fetches).
    pub data_net: CostModel,
    /// Simulated engine: also execute the tasks to produce real
    /// correspondences (small workloads only).
    pub execute_in_sim: bool,
    /// Simulated engine: calibrate per-pair cost by really matching a
    /// sample (otherwise use the strategy's default constants).
    pub calibrate: bool,
    /// Simulated engine: use these cost params verbatim (skips
    /// calibration).  Sweeps MUST pin the cost once and reuse it —
    /// re-calibrating per configuration injects real-timer noise into
    /// virtual-time ratios.
    pub cost_override: Option<CostParams>,
    /// Simulated node failures (virtual ns, node index).
    pub failures: Vec<(u64, usize)>,
}

impl WorkflowConfig {
    /// Blocking-based partitioning by product type, simulated engine —
    /// the paper's primary configuration.
    pub fn blocking_based(kind: StrategyKind) -> WorkflowConfig {
        WorkflowConfig {
            strategy: MatchStrategy::new(kind),
            partitioning: PartitioningChoice::BlockingBased {
                method: BlockingMethod::product_type(),
                max_size: None,
                min_size: default_min_size(kind),
            },
            engine: EngineChoice::Simulated,
            cache_capacity: 0,
            policy: crate::coordinator::Policy::Affinity,
            data_replicas: 1,
            batch: 1,
            bind: "127.0.0.1".to_string(),
            net: CostModel::lan(),
            data_net: CostModel::dbms(),
            execute_in_sim: false,
            calibrate: true,
            cost_override: None,
            failures: Vec::new(),
        }
    }

    /// Size-based (Cartesian) partitioning, simulated engine.
    pub fn size_based(kind: StrategyKind) -> WorkflowConfig {
        WorkflowConfig {
            partitioning: PartitioningChoice::SizeBased { max_size: None },
            ..WorkflowConfig::blocking_based(kind)
        }
    }

    /// Select the execution engine (builder style).
    pub fn with_engine(mut self, engine: EngineChoice) -> Self {
        self.engine = engine;
        self
    }

    /// Set the per-service partition-cache capacity (builder style).
    pub fn with_cache(mut self, c: usize) -> Self {
        self.cache_capacity = c;
        self
    }

    /// Pin simulator cost params verbatim (builder style).
    pub fn with_cost(mut self, cost: CostParams) -> Self {
        self.cost_override = Some(cost);
        self
    }

    /// Distributed engine: run this many data-plane servers (builder
    /// style; clamped to ≥ 1 at run time).
    pub fn with_data_replicas(mut self, n: usize) -> Self {
        self.data_replicas = n;
        self
    }

    /// Distributed engine: pull this many tasks per control round
    /// trip (builder style; clamped to ≥ 1 at run time).
    pub fn with_batch(mut self, k: usize) -> Self {
        self.batch = k;
        self
    }
}

/// The paper's favorable maximum partition sizes (Fig 6): 1,000 for WAM,
/// 500 for LRM.
pub fn default_max_size(kind: StrategyKind) -> usize {
    match kind {
        StrategyKind::Wam => 1000,
        StrategyKind::Lrm => 500,
    }
}

/// The paper's favorable minimum partition sizes (Fig 7): 200 for WAM,
/// 100 for LRM.
pub fn default_min_size(kind: StrategyKind) -> usize {
    match kind {
        StrategyKind::Wam => 200,
        StrategyKind::Lrm => 100,
    }
}

/// Workflow outcome: merged result + run metrics + structural info.
pub struct WorkflowOutcome {
    /// Merged, deduplicated correspondences.
    pub result: MatchResult,
    /// Engine metrics (wall clock or virtual time, see engine docs).
    pub metrics: RunMetrics,
    /// Partitions after tuning.
    pub n_partitions: usize,
    /// Partitions that came from the misc block (§3.2).
    pub n_misc_partitions: usize,
    /// Match tasks generated.
    pub n_tasks: usize,
    /// Wall-clock time of the whole workflow (pre+match+merge).
    pub elapsed: std::time::Duration,
    /// Cost params used by the simulator (after calibration).
    pub cost: Option<CostParams>,
}

/// Build the partition set for a workflow (pre-processing half).
pub fn build_partitions(
    dataset: &Dataset,
    cfg: &WorkflowConfig,
    ce: &ComputingEnv,
) -> Result<PartitionSet> {
    let kind = cfg.strategy.kind;
    // An explicit max_size overrides the memory model (experiments like
    // Fig 6 sweep past the memory-restricted size on purpose, paying the
    // paging penalty); `None` derives m from §3.1's formula, clamped to
    // the strategy's empirically favorable size.
    let mem_cap = max_partition_size(ce, kind);
    let auto = || default_max_size(kind).min(mem_cap.max(1));
    match &cfg.partitioning {
        PartitioningChoice::SizeBased { max_size } => {
            let m = max_size.unwrap_or_else(auto);
            let ids: Vec<EntityId> =
                dataset.entities.iter().map(|e| e.id).collect();
            Ok(partition_size_based(&ids, m))
        }
        PartitioningChoice::BlockingBased {
            method,
            max_size,
            min_size,
        } => {
            let m = max_size.unwrap_or_else(auto);
            if *min_size > m {
                bail!("min_size {min_size} exceeds max partition size {m}");
            }
            let blocks = method.run(dataset);
            Ok(tune(&blocks, TuningConfig::new(m, *min_size)))
        }
    }
}

/// Run a complete match workflow.
pub fn run_workflow(
    dataset: &Dataset,
    cfg: &WorkflowConfig,
    ce: &ComputingEnv,
) -> Result<WorkflowOutcome> {
    let started = Instant::now();
    let parts = build_partitions(dataset, cfg, ce)?;
    let tasks: Vec<MatchTask> = generate_tasks(&parts);
    let store = std::sync::Arc::new(DataService::build(dataset, &parts));
    let n_tasks = tasks.len();
    let n_partitions = parts.len();
    let n_misc = parts.n_misc();

    let (metrics, correspondences, cost) = match cfg.engine {
        EngineChoice::Threads => {
            let exec = RustExecutor::new(cfg.strategy);
            let out = threads::run(
                ce,
                &parts,
                tasks,
                &store,
                &exec,
                threads::ThreadConfig {
                    cache_capacity: cfg.cache_capacity,
                    policy: cfg.policy,
                },
            );
            (out.metrics, out.correspondences, None)
        }
        EngineChoice::Distributed => {
            let exec: std::sync::Arc<dyn crate::worker::TaskExecutor> =
                std::sync::Arc::new(RustExecutor::new(cfg.strategy));
            let out = dist::run(
                ce,
                &parts,
                tasks,
                store.clone(),
                exec,
                dist::DistConfig {
                    cache_capacity: cfg.cache_capacity,
                    policy: cfg.policy,
                    data_replicas: cfg.data_replicas.max(1),
                    batch: cfg.batch.max(1),
                    bind: cfg.bind.clone(),
                    ..dist::DistConfig::default()
                },
            )?;
            (out.metrics, out.correspondences, None)
        }
        EngineChoice::Simulated => {
            let cost = if let Some(cost) = cfg.cost_override {
                cost
            } else if cfg.calibrate {
                calibrate::calibrated_params(
                    dataset,
                    cfg.strategy.kind,
                    120,
                    0xCA11B,
                )
            } else {
                CostParams::default_for(cfg.strategy.kind)
            };
            let mut sim_cfg = sim::SimConfig::new(cfg.strategy.kind, cost);
            sim_cfg.net = cfg.net;
            sim_cfg.data_net = cfg.data_net;
            sim_cfg.cache_capacity = cfg.cache_capacity;
            sim_cfg.policy = cfg.policy;
            sim_cfg.failures = cfg.failures.clone();
            if cfg.execute_in_sim {
                sim_cfg.execute =
                    Some(Box::new(RustExecutor::new(cfg.strategy)));
            }
            let out = sim::run(ce, &parts, tasks, &store, sim_cfg);
            (out.metrics, out.correspondences, Some(cost))
        }
    };

    // merge per-task outputs (the workflow service's post-processing)
    let mut result = MatchResult::new();
    for c in correspondences {
        result.add(c);
    }

    Ok(WorkflowOutcome {
        result,
        metrics,
        n_partitions,
        n_misc_partitions: n_misc,
        n_tasks,
        elapsed: started.elapsed(),
        cost,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;

    fn tiny_ce() -> ComputingEnv {
        ComputingEnv::new(1, 2, crate::util::GIB)
    }

    #[test]
    fn size_based_thread_workflow_finds_duplicates() {
        let data = GeneratorConfig::tiny().with_seed(21).generate();
        let cfg = WorkflowConfig::size_based(StrategyKind::Wam)
            .with_engine(EngineChoice::Threads);
        let out = run_workflow(&data, &cfg, &tiny_ce()).unwrap();
        assert!(out.n_tasks >= out.n_partitions);
        let q = out.result.quality(&data.truth);
        assert!(q.recall > 0.8, "recall {}", q.recall);
        assert!(q.precision > 0.5, "precision {}", q.precision);
    }

    #[test]
    fn blocking_based_reduces_comparisons() {
        let data = GeneratorConfig::tiny().with_entities(1200).generate();
        let ce = tiny_ce();
        let size = run_workflow(
            &data,
            &WorkflowConfig::size_based(StrategyKind::Wam)
                .with_engine(EngineChoice::Threads),
            &ce,
        )
        .unwrap();
        // tuning bounds sized to the dataset: ~37 product types over
        // 1,200 entities → blocks of ~10-150; max 200 keeps aggregates
        // small enough that blocking actually prunes the search space
        let mut bcfg = WorkflowConfig::blocking_based(StrategyKind::Wam)
            .with_engine(EngineChoice::Threads);
        if let PartitioningChoice::BlockingBased {
            max_size, min_size, ..
        } = &mut bcfg.partitioning
        {
            *max_size = Some(200);
            *min_size = 40;
        }
        let block = run_workflow(&data, &bcfg, &ce).unwrap();
        assert!(
            block.metrics.comparisons < size.metrics.comparisons / 2,
            "blocking {} vs cartesian {}",
            block.metrics.comparisons,
            size.metrics.comparisons
        );
        // and loses almost no recall on same-type duplicates (misc block
        // handling keeps entities with missing product type matchable)
        let qb = block.result.quality(&data.truth);
        let qs = size.result.quality(&data.truth);
        assert!(
            qb.recall >= qs.recall - 0.05,
            "blocking recall {} vs {}",
            qb.recall,
            qs.recall
        );
    }

    #[test]
    fn simulated_workflow_produces_metrics_without_matching() {
        let data = GeneratorConfig::tiny().generate();
        let mut cfg = WorkflowConfig::blocking_based(StrategyKind::Lrm);
        cfg.calibrate = false; // keep test fast & deterministic
        let out = run_workflow(&data, &cfg, &ComputingEnv::paper_testbed(4))
            .unwrap();
        assert!(out.metrics.makespan_ns > 0);
        assert_eq!(out.result.len(), 0, "sim without execute");
        assert!(out.cost.is_some());
    }

    #[test]
    fn sim_execute_equals_threads_result() {
        let data = GeneratorConfig::tiny().with_seed(9).generate();
        let base = WorkflowConfig::blocking_based(StrategyKind::Wam);
        let t = run_workflow(
            &data,
            &base.clone().with_engine(EngineChoice::Threads),
            &tiny_ce(),
        )
        .unwrap();
        let mut sim_cfg = base;
        sim_cfg.execute_in_sim = true;
        sim_cfg.calibrate = false;
        let s =
            run_workflow(&data, &sim_cfg, &ComputingEnv::paper_testbed(2))
                .unwrap();
        assert_eq!(t.result.len(), s.result.len());
        for c in t.result.iter() {
            assert!(s.result.contains(c.e1, c.e2));
        }
    }

    #[test]
    fn memory_model_caps_partition_size() {
        let data = GeneratorConfig::tiny().generate();
        // tiny memory → small partitions even though default is 500
        let ce = ComputingEnv::new(1, 4, 64 * crate::util::MIB);
        let cfg = WorkflowConfig::size_based(StrategyKind::Lrm);
        let parts = build_partitions(&data, &cfg, &ce).unwrap();
        let cap = max_partition_size(&ce, StrategyKind::Lrm);
        assert!(parts.max_size() <= cap);
        assert!(cap < 500, "cap {cap} should bind");
    }

    #[test]
    fn invalid_min_size_rejected() {
        let data = GeneratorConfig::tiny().generate();
        let mut cfg = WorkflowConfig::blocking_based(StrategyKind::Wam);
        if let PartitioningChoice::BlockingBased { min_size, .. } =
            &mut cfg.partitioning
        {
            *min_size = 10_000;
        }
        assert!(run_workflow(&data, &cfg, &tiny_ce()).is_err());
    }
}
