//! Multi-source match workflows (paper §3.3), first-class.
//!
//! Three strategies for matching two or more input sources:
//!
//! * [`union_sources`] — take the union of the sources (schemas
//!   must already be aligned) and run the standard single-source
//!   workflow; finds both cross-source and intra-source duplicates.
//! * [`run_two_source_workflow`] with [`TwoSourceMode::Cartesian`] —
//!   duplicate-free sources: size-partition each source and generate
//!   only the `m·n` cross-source tasks.
//! * [`TwoSourceMode::Blocked`] — apply the same blocking to both
//!   sources, tune each side, and match corresponding blocks across
//!   sources; misc partitions of either side are matched against all
//!   partitions of the *other* source.
//!
//! Cross-source execution keeps two partition namespaces (one store per
//! source); tasks carry (left ∈ A, right ∈ B).

use crate::blocking::BlockingMethod;
use crate::cluster::ComputingEnv;
use crate::matching::MatchStrategy;
use crate::model::{Correspondence, Dataset, EntityId, MatchResult};
use crate::partition::blocking_based::tune_paired;
use crate::partition::{
    generate_tasks_two_sources_blocked, generate_tasks_two_sources_cartesian,
    max_partition_size, partition_size_based, PartitionSet, TuningConfig,
};
use crate::store::DataService;

use anyhow::Result;

/// How two duplicate-free sources are matched against each other.
#[derive(Clone, Debug)]
pub enum TwoSourceMode {
    /// Cartesian product across sources (`m·n` tasks).
    Cartesian {
        /// Maximum partition size (`None` derives from the memory model).
        max_size: Option<usize>,
    },
    /// Same blocking on both sides, matched per corresponding block.
    Blocked {
        /// Blocking method applied to both sources.
        method: BlockingMethod,
        /// Maximum partition size (`None` derives from the memory model).
        max_size: Option<usize>,
        /// Minimum partition size for aggregation.
        min_size: usize,
    },
}

/// Outcome of a two-source run.
pub struct TwoSourceOutcome {
    /// Cross-source correspondences.
    pub result: MatchResult,
    /// Match tasks executed.
    pub n_tasks: usize,
    /// Pair comparisons evaluated.
    pub comparisons: u64,
    /// Task-count comparison: what a union-based run would have cost.
    pub union_equivalent_tasks: usize,
}

/// §3.3 union approach: combine sources, then the caller runs the usual
/// [`super::run_workflow`] on the returned dataset.
pub fn union_sources(sources: Vec<Dataset>) -> Dataset {
    Dataset::union(sources)
}

fn partitions_for(
    source_a: &Dataset,
    source_b: &Dataset,
    mode: &TwoSourceMode,
    strategy: &MatchStrategy,
    ce: &ComputingEnv,
) -> Result<(PartitionSet, PartitionSet)> {
    let mem_cap = max_partition_size(ce, strategy.kind).max(1);
    Ok(match mode {
        TwoSourceMode::Cartesian { max_size } => {
            let m = max_size.unwrap_or(mem_cap).min(mem_cap);
            let mk = |source: &Dataset| {
                let ids: Vec<EntityId> =
                    source.entities.iter().map(|e| e.id).collect();
                partition_size_based(&ids, m)
            };
            (mk(source_a), mk(source_b))
        }
        TwoSourceMode::Blocked {
            method,
            max_size,
            min_size,
        } => {
            // paired tuning: identical split/aggregate decisions on both
            // sides so corresponding partitions align by key (§3.3)
            let m = max_size.unwrap_or(mem_cap).min(mem_cap);
            tune_paired(
                &method.run(source_a),
                &method.run(source_b),
                TuningConfig::new(m, (*min_size).min(m)),
            )
        }
    })
}

/// Match two **duplicate-free** sources against each other.  Entities of
/// the same source are never compared (their sources guarantee
/// uniqueness), which is the §3.3 saving: `m·n` tasks instead of
/// `(m+n)(m+n−1)/2`.
///
/// Execution is single-process (the exact matchers run over
/// real data); the returned correspondences use per-source entity ids —
/// `e1` from source A, `e2` from source B.
pub fn run_two_source_workflow(
    source_a: &Dataset,
    source_b: &Dataset,
    mode: &TwoSourceMode,
    strategy: MatchStrategy,
    ce: &ComputingEnv,
) -> Result<TwoSourceOutcome> {
    let (parts_a, parts_b) =
        partitions_for(source_a, source_b, mode, &strategy, ce)?;
    let tasks = match mode {
        TwoSourceMode::Cartesian { .. } => {
            generate_tasks_two_sources_cartesian(&parts_a, &parts_b)
        }
        TwoSourceMode::Blocked { .. } => {
            generate_tasks_two_sources_blocked(&parts_a, &parts_b)
        }
    };
    let store_a = DataService::build(source_a, &parts_a);
    let store_b = DataService::build(source_b, &parts_b);

    // Both sources number their entities from 0, so results live in a
    // combined namespace: A keeps its ids, B ids are offset by |A|.
    // (The executor's same-id guard is for overlapping single-source
    // partitions and must not fire across namespaces, hence the manual
    // comparison loop.)
    let offset = source_a.len() as u32;
    let mut result = MatchResult::new();
    let mut comparisons = 0u64;
    for (task, _) in &tasks {
        let left = store_a
            .fetch(task.left)
            .expect("partition named by the plan");
        let right = store_b
            .fetch(task.right)
            .expect("partition named by the plan");
        comparisons += left.len() as u64 * right.len() as u64;
        for i in 0..left.len() {
            for j in 0..right.len() {
                let sim = strategy
                    .similarity(&left.features[i], &right.features[j]);
                if sim >= strategy.threshold {
                    result.add(Correspondence::new(
                        left.entities[i],
                        EntityId(right.entities[j].0 + offset),
                        sim as f32,
                    ));
                }
            }
        }
    }

    let union_p = parts_a.len() + parts_b.len();
    Ok(TwoSourceOutcome {
        result,
        n_tasks: tasks.len(),
        comparisons,
        union_equivalent_tasks: union_p + union_p * (union_p - 1) / 2,
    })
}

/// Split a dataset with known duplicate clusters into two duplicate-free
/// sources (test/demo helper: each source keeps at most one offer per
/// real-world product; cross-source pairs remain the ground truth).
pub fn split_duplicate_free(
    dataset: &Dataset,
    truth: &[(EntityId, EntityId)],
) -> (Dataset, Dataset, Vec<(EntityId, EntityId)>) {
    // union-find-lite over truth to get cluster representatives
    let n = dataset.len();
    let mut cluster = vec![usize::MAX; n];
    let mut next_cluster = 0usize;
    for &(a, b) in truth {
        let (ai, bi) = (a.0 as usize, b.0 as usize);
        match (cluster[ai], cluster[bi]) {
            (usize::MAX, usize::MAX) => {
                cluster[ai] = next_cluster;
                cluster[bi] = next_cluster;
                next_cluster += 1;
            }
            (ca, usize::MAX) => cluster[bi] = ca,
            (usize::MAX, cb) => cluster[ai] = cb,
            (ca, cb) if ca != cb => {
                for c in cluster.iter_mut() {
                    if *c == cb {
                        *c = ca;
                    }
                }
            }
            _ => {}
        }
    }
    let mut a = Dataset::new(dataset.schema.clone());
    let mut b = Dataset::new(dataset.schema.clone());
    let mut seen_in_a: std::collections::HashSet<usize> =
        std::collections::HashSet::new();
    // map original id -> (source, new id)
    let mut placed: Vec<Option<(bool, u32)>> = vec![None; n];
    for e in &dataset.entities {
        let i = e.id.0 as usize;
        let to_a = match cluster[i] {
            usize::MAX => i % 2 == 0,
            c => seen_in_a.insert(c),
        };
        let target = if to_a { &mut a } else { &mut b };
        let mut copy = e.clone();
        copy.id = EntityId(target.len() as u32);
        placed[i] = Some((to_a, copy.id.0));
        target.push(copy);
    }
    // cross-source truth in the new id spaces
    let mut cross_truth = Vec::new();
    for &(x, y) in truth {
        let (px, py) = (
            placed[x.0 as usize].unwrap(),
            placed[y.0 as usize].unwrap(),
        );
        if px.0 != py.0 {
            let (ida, idb) = if px.0 { (px.1, py.1) } else { (py.1, px.1) };
            cross_truth.push((EntityId(ida), EntityId(idb)));
        }
    }
    (a, b, cross_truth)
}

/// Quality of a two-source result against cross-source truth.  The
/// result uses the combined namespace (B ids offset by |A|); the truth
/// pairs are (A id, B id) in their own spaces, so pass `offset_b = |A|`.
pub fn cross_quality(
    result: &MatchResult,
    cross_truth: &[(EntityId, EntityId)],
    offset_b: u32,
) -> crate::model::Quality {
    let found: std::collections::HashSet<(u32, u32)> = result
        .iter()
        .map(|c: Correspondence| {
            let (x, y) = c.pair();
            (x.0.min(y.0), x.0.max(y.0))
        })
        .collect();
    let truth: std::collections::HashSet<(u32, u32)> = cross_truth
        .iter()
        .map(|&(a, b)| {
            let b = b.0 + offset_b;
            (a.0.min(b), a.0.max(b))
        })
        .collect();
    let tp = found.intersection(&truth).count();
    let precision = if found.is_empty() {
        0.0
    } else {
        tp as f64 / found.len() as f64
    };
    let recall = if truth.is_empty() {
        0.0
    } else {
        tp as f64 / truth.len() as f64
    };
    let f1 = if precision + recall == 0.0 {
        0.0
    } else {
        2.0 * precision * recall / (precision + recall)
    };
    crate::model::Quality {
        true_positives: tp,
        predicted: found.len(),
        actual: truth.len(),
        precision,
        recall,
        f1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::matching::StrategyKind;
    use crate::util::GIB;

    fn setup() -> (Dataset, Dataset, Vec<(EntityId, EntityId)>) {
        let data = GeneratorConfig::tiny().with_entities(600).generate();
        split_duplicate_free(&data.dataset, &data.truth)
    }

    #[test]
    fn split_is_duplicate_free_and_covers() {
        let data = GeneratorConfig::tiny().with_entities(600).generate();
        let (a, b, cross) =
            split_duplicate_free(&data.dataset, &data.truth);
        assert_eq!(a.len() + b.len(), data.dataset.len());
        assert!(!cross.is_empty());
        // no truth pair may live entirely inside one source: since each
        // cluster contributes exactly one entity to A, pairs within A
        // are impossible; pairs within B are possible for clusters of
        // size >= 3 — tolerate those but they must be a minority
        let within_b = data.truth.len() - cross.len();
        assert!(
            within_b * 3 <= data.truth.len(),
            "{within_b} of {} pairs not cross-source",
            data.truth.len()
        );
    }

    #[test]
    fn cartesian_mode_mn_tasks() {
        let (a, b, _) = setup();
        let ce = ComputingEnv::new(1, 2, GIB);
        let mode = TwoSourceMode::Cartesian {
            max_size: Some(100),
        };
        let out = run_two_source_workflow(
            &a,
            &b,
            &mode,
            MatchStrategy::new(StrategyKind::Wam),
            &ce,
        )
        .unwrap();
        let (m, n) = (a.len().div_ceil(100), b.len().div_ceil(100));
        assert_eq!(out.n_tasks, m * n);
        assert!(out.n_tasks < out.union_equivalent_tasks);
        assert_eq!(out.comparisons, (a.len() * b.len()) as u64);
    }

    #[test]
    fn cartesian_finds_cross_duplicates() {
        let (a, b, cross) = setup();
        let ce = ComputingEnv::new(1, 2, GIB);
        let out = run_two_source_workflow(
            &a,
            &b,
            &TwoSourceMode::Cartesian {
                max_size: Some(100),
            },
            MatchStrategy::new(StrategyKind::Wam),
            &ce,
        )
        .unwrap();
        let q = cross_quality(&out.result, &cross, a.len() as u32);
        assert!(q.recall > 0.75, "recall {}", q.recall);
    }

    #[test]
    fn blocked_mode_fewer_comparisons_similar_recall() {
        let (a, b, cross) = setup();
        let ce = ComputingEnv::new(1, 2, GIB);
        let cart = run_two_source_workflow(
            &a,
            &b,
            &TwoSourceMode::Cartesian {
                max_size: Some(100),
            },
            MatchStrategy::new(StrategyKind::Wam),
            &ce,
        )
        .unwrap();
        let blocked = run_two_source_workflow(
            &a,
            &b,
            &TwoSourceMode::Blocked {
                method: BlockingMethod::product_type(),
                max_size: Some(100),
                min_size: 20,
            },
            MatchStrategy::new(StrategyKind::Wam),
            &ce,
        )
        .unwrap();
        assert!(blocked.comparisons < cart.comparisons);
        let qc = cross_quality(&cart.result, &cross, a.len() as u32);
        let qb = cross_quality(&blocked.result, &cross, a.len() as u32);
        assert!(
            qb.recall >= qc.recall - 0.05,
            "blocked {} vs cartesian {}",
            qb.recall,
            qc.recall
        );
    }

    #[test]
    fn union_equivalent_counts() {
        let (a, b, _) = setup();
        let ce = ComputingEnv::new(1, 2, GIB);
        let out = run_two_source_workflow(
            &a,
            &b,
            &TwoSourceMode::Cartesian {
                max_size: Some(50),
            },
            MatchStrategy::new(StrategyKind::Wam),
            &ce,
        )
        .unwrap();
        // m·n < (m+n)(m+n−1)/2 always (m, n >= 1, m+n >= 2)
        assert!(out.n_tasks < out.union_equivalent_tasks);
    }
}
