//! The workflow service's task list and affinity-based scheduling
//! (paper §4).
//!
//! Pull-based: whenever a match service reports a completed task (with
//! its piggybacked cache status), the workflow service assigns it a new
//! one — preferably a task whose needed partitions are already cached at
//! that service.  Pull scheduling gives dynamic load balancing and copes
//! with heterogeneous nodes for free; the affinity preference adds cache
//! locality.  Failure handling (paper §4): when a match service stops
//! responding, its in-flight tasks are put back on the open list.
//!
//! Failure handling carries a **generation check**: failing a service
//! bumps its generation and marks it dead, so a "resurrected" service
//! — one declared dead that reports anyway — can neither pull new
//! tasks nor complete old ones ([`Scheduler::next_task`] returns
//! `None`, [`Scheduler::try_report_complete`] drops the report).
//! Without it, a zombie could be handed the re-queued copy of its own
//! task and its straggler completion would then satisfy the new
//! assignment — a double-completion.  Revival is explicit: only
//! [`Scheduler::add_service`] (a real re-join; the wire layer always
//! grants a fresh [`ServiceId`]) clears the dead mark.
//!
//! For the v3 batched wire protocol, [`Scheduler::next_tasks_for`]
//! assigns up to `k` tasks in one call, re-ranking the open list
//! between picks so affinity and replica-coverage ordering hold
//! *within* the batch, not just at its head.
//!
//! The §3.1 **memory model** reaches the scheduler through
//! [`Scheduler::reject_task`] (wire `TaskRejected`, protocol v4): a
//! node that cannot fit an assigned task's memory footprint hands it
//! back, the task is re-queued *marked oversize for that service*, and
//! [`Scheduler::next_task`] never offers it to that service again —
//! other nodes (with larger budgets) still receive it, so an oversize
//! task is re-routed instead of lost or endlessly ping-ponged.
//!
//! When **every** live service has rejected a task, re-routing cannot
//! help — the paper's §3 answer is to *reshape* the task, not bounce
//! it until the run times out.  Fed the plan's split metadata
//! ([`Scheduler::set_task_meta`]) and each node's budget reported at
//! join ([`Scheduler::set_service_budget`], protocol v5), the
//! scheduler splits the unplaceable task's pair space into sub-tasks
//! that fit the **smallest live budget** — triangles along the
//! diagonal plus the rectangles between chunks, Kolb et al.'s
//! BlockSplit applied at run time — re-queues them carrying a
//! [`TaskSpan`] each, and merges their completions so the original
//! task counts as completed **exactly once**.  A task that cannot be
//! split any further (no metadata, or a single pair already exceeds
//! the smallest budget) raises the typed [`PlanMisfit`] error instead:
//! the workflow server and the dist engine surface "this plan does not
//! fit this cluster" immediately, never burning the run timeout.
//!
//! With a **replicated data plane** the scheduler additionally tracks
//! how many data replicas hold each partition
//! ([`Scheduler::add_replica_coverage`], fed by `ReplicaAnnounce`).
//! Among tasks with equal cache affinity, assignment prefers the task
//! whose partitions are the most widely replicated — those fetches can
//! be served by a nearby, less-loaded replica (the paper's §5 caching +
//! affinity strategy, extended across the network).
//!
//! **Multi-tenant fair scheduling (protocol v7).**  A resident
//! workflow service runs many concurrently submitted plans over one
//! task list.  Each submitted plan becomes a *tenant*
//! ([`Scheduler::add_tenant_tasks`]); the seed workflow the scheduler
//! was constructed with is tenant `0`.  While more than one tenant has
//! open tasks, every pick first chooses the tenant by **round-robin
//! across tenants with assignable work** (skipping tenants at their
//! in-flight quota), then applies the normal affinity / replica /
//! FIFO ranking *within* that tenant's tasks — so a heavy plan cannot
//! starve a light one, and among continuously backlogged tenants the
//! number of assignments never diverges by more than one per pick
//! (the fairness property test in this module).  Runtime-split
//! sub-tasks inherit their root's tenant.  An unsplittable tenant
//! task raises a **per-tenant** misfit ([`Scheduler::tenant_misfit`])
//! and drains only that tenant — the cluster and every other tenant
//! keep running — whereas a tenant-0 misfit stays the terminal
//! [`Scheduler::misfit`] it always was.  With a single tenant the
//! selection layer disappears entirely (the O(1) fast paths below are
//! untouched).

use crate::obs::{TraceEventKind, Tracer};
use crate::partition::{MatchTask, PartitionId, TaskSpan};
use std::collections::{HashMap, HashSet, VecDeque};
use std::fmt;
use std::sync::Arc;

/// Identifier of a match service (one per node).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct ServiceId(pub usize);

/// Typed terminal error of the §3.1 memory model: a task was rejected
/// by every live match service and cannot be split into smaller
/// sub-tasks, so the plan can never complete on this cluster.  The
/// workflow server ([`crate::service::WorkflowServiceServer`]) and the
/// dist engine surface this immediately (fail fast) instead of letting
/// the run idle until its timeout.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanMisfit {
    /// The unplaceable task.
    pub task_id: u32,
    /// Its §3.1 memory footprint (`0` = unknown: the run carried no
    /// plan footprints).
    pub mem_bytes: u64,
    /// Smallest per-task budget among the live services when the task
    /// became unplaceable (`0` = no budget was ever reported).
    pub smallest_budget: u64,
}

impl fmt::Display for PlanMisfit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "plan does not fit this cluster: task {} (§3.1 footprint \
             {}) was rejected by every live match service (smallest \
             budget {}) and cannot be split further",
            self.task_id,
            crate::util::fmt_bytes(self.mem_bytes),
            crate::util::fmt_bytes(self.smallest_budget),
        )
    }
}

impl std::error::Error for PlanMisfit {}

/// Scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Plain FIFO over the central task list.
    Fifo,
    /// Prefer tasks whose partitions are cached at the requesting
    /// service (the paper's affinity-based scheduling).
    Affinity,
}

/// Central task list + approximate cache status.
#[derive(Debug)]
pub struct Scheduler {
    open: VecDeque<MatchTask>,
    /// task id → (owner, owner's generation at assignment, task).
    in_flight: HashMap<u32, (ServiceId, u32, MatchTask)>,
    cache_status: HashMap<ServiceId, HashSet<PartitionId>>,
    /// Membership epoch per service: bumped by [`Scheduler::fail_service`],
    /// so completions from before a failure can never satisfy an
    /// assignment made after it.
    generation: HashMap<ServiceId, u32>,
    /// Services declared dead and not (re-)added since.
    dead: HashSet<ServiceId>,
    /// task id → services that rejected it as oversize (§3.1 memory
    /// model): the task is never re-offered to those services.
    oversize: HashMap<u32, HashSet<ServiceId>>,
    /// task id → §3.1 memory footprint: the plan's for root tasks,
    /// computed at split time for sub-tasks.  Served with every
    /// assignment (protocol v4/v5).
    mem: HashMap<u32, u64>,
    /// task id → (left, right) partition entity counts — the split
    /// metadata fed from the plan.  A task without an entry cannot be
    /// runtime-split (an all-rejected one then raises [`PlanMisfit`]).
    sizes: HashMap<u32, (u32, u32)>,
    /// Pair-space spans of runtime-split sub-tasks.
    spans: HashMap<u32, TaskSpan>,
    /// sub-task id → root (plan) task id it descends from.
    split_parent: HashMap<u32, u32>,
    /// root task id → descendants not yet completed; the root counts
    /// as completed exactly once, when this reaches zero.
    split_outstanding: HashMap<u32, usize>,
    /// §3.1 per-task budget reported by each live service at join
    /// (absent = unlimited).  Sub-tasks are sized to the smallest.
    budgets: HashMap<ServiceId, u64>,
    /// Reshaping waits until this many services have ever joined —
    /// the engine's expected cluster size.  Guards against a fast
    /// first node declaring a task unplaceable while its (roomier)
    /// peers are still connecting.
    min_split_services: usize,
    /// Next sub-task id (kept above every plan task id).
    next_split_id: u32,
    /// Tasks (plan tasks or sub-tasks) split at run time.
    runtime_splits: u64,
    /// Terminal §3.1 misfit; sticky once set (first wins).
    misfit: Option<PlanMisfit>,
    /// partition → number of data replicas announced as holding it.
    replica_coverage: HashMap<PartitionId, u32>,
    /// root (plan) task id → tenant that submitted it (v7).  Tasks
    /// without an entry belong to tenant `0`, the seed workflow;
    /// runtime-split sub-tasks inherit their root's tenant.  Empty
    /// unless plans have been submitted — the single-tenant fast
    /// paths key off this.
    tenant_of: HashMap<u32, u32>,
    /// tenant → max tasks in flight at once (absent = unlimited).
    tenant_quota: HashMap<u32, usize>,
    /// tenant → root tasks completed (tenant 0 not tracked here).
    tenant_completed: HashMap<u32, usize>,
    /// tenant → root tasks submitted (tenant 0 not tracked here).
    tenant_total: HashMap<u32, usize>,
    /// Per-tenant §3.1 misfits: an unsplittable *tenant* task fails
    /// only its tenant, never the cluster (v7).
    tenant_misfits: HashMap<u32, PlanMisfit>,
    /// Round-robin cursor of the tenant selection layer.
    rr_last: u32,
    /// Lifecycle tracer ([`crate::obs::trace`]); every scheduling
    /// decision is recorded when set.
    tracer: Option<Arc<Tracer>>,
    policy: Policy,
    /// Tasks assigned with at least one affinity (cached-partition) hit.
    pub affinity_assignments: u64,
    completed: usize,
    total: usize,
}

impl Scheduler {
    /// Seed the central task list under the given policy.
    pub fn new(tasks: Vec<MatchTask>, policy: Policy) -> Scheduler {
        let total = tasks.len();
        let next_split_id = tasks
            .iter()
            .map(|t| t.id)
            .max()
            .map_or(0, |m| m + 1);
        Scheduler {
            open: tasks.into(),
            in_flight: HashMap::new(),
            cache_status: HashMap::new(),
            generation: HashMap::new(),
            dead: HashSet::new(),
            oversize: HashMap::new(),
            mem: HashMap::new(),
            sizes: HashMap::new(),
            spans: HashMap::new(),
            split_parent: HashMap::new(),
            split_outstanding: HashMap::new(),
            budgets: HashMap::new(),
            min_split_services: 1,
            next_split_id,
            runtime_splits: 0,
            misfit: None,
            replica_coverage: HashMap::new(),
            tenant_of: HashMap::new(),
            tenant_quota: HashMap::new(),
            tenant_completed: HashMap::new(),
            tenant_total: HashMap::new(),
            tenant_misfits: HashMap::new(),
            rr_last: 0,
            tracer: None,
            policy,
            affinity_assignments: 0,
            completed: 0,
            total,
        }
    }

    /// Attach the plan's per-task §3.1 footprints and `(left, right)`
    /// partition sizes.  Footprints travel with every assignment;
    /// sizes are the split metadata that lets the scheduler reshape a
    /// task every live service has rejected (see the module docs).
    pub fn set_task_meta(
        &mut self,
        mem: HashMap<u32, u64>,
        sizes: HashMap<u32, (u32, u32)>,
    ) {
        // merged, not replaced: tenant plans admitted later
        // (`add_tenant_tasks`) bring their own entries
        self.mem.extend(mem);
        self.sizes.extend(sizes);
    }

    /// Record the §3.1 per-task budget `service` reported at join
    /// (`None` = unlimited).  Feeds runtime splitting: sub-tasks of an
    /// unplaceable task are sized to the smallest live budget.
    pub fn set_service_budget(
        &mut self,
        service: ServiceId,
        budget: Option<u64>,
    ) {
        match budget {
            Some(b) => {
                self.budgets.insert(service, b);
            }
            None => {
                self.budgets.remove(&service);
            }
        }
    }

    /// Attach a lifecycle tracer ([`crate::obs::trace`]): every task
    /// currently open is recorded as `Planned` + `Queued`, and every
    /// scheduling decision from here on (assignment, rejection,
    /// splitting, requeueing, completion merging) emits its event.
    /// Call right after [`Scheduler::new`], before execution starts.
    pub fn set_tracer(&mut self, tracer: Arc<Tracer>) {
        for t in &self.open {
            tracer.record(t.id, TraceEventKind::Planned, None, None);
            tracer.record(t.id, TraceEventKind::Queued, None, None);
        }
        self.tracer = Some(tracer);
    }

    /// The attached lifecycle tracer, if any — engines clone it to
    /// stamp their own node-side events (`PartitionsFetched`,
    /// `Executed`) into the same ring.
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    /// Record `kind` for `task` when a tracer is attached.
    fn trace(
        &self,
        task: u32,
        kind: TraceEventKind,
        node: Option<ServiceId>,
        parent: Option<u32>,
    ) {
        if let Some(t) = &self.tracer {
            t.record(task, kind, node.map(|s| s.0 as u64), parent);
        }
    }

    /// Defer runtime splitting (and the misfit verdict) until `n`
    /// services have ever joined.  The dist engine sets its node
    /// count here, so a fast first node that rejects everything while
    /// its roomier peers are still connecting cannot prematurely
    /// declare a task unplaceable.  Clamped to ≥ 1; default 1 (an
    /// elastic cluster splits as soon as all *current* members have
    /// rejected).
    pub fn set_min_split_services(&mut self, n: usize) {
        self.min_split_services = n.max(1);
    }

    /// The §3.1 footprint served with an assignment of `task_id`
    /// (0 when the run carries no plan footprints).
    pub fn mem_of(&self, task_id: u32) -> u64 {
        self.mem.get(&task_id).copied().unwrap_or(0)
    }

    /// The pair-space span of a runtime-split sub-task (`None` for
    /// plan tasks): travels with the assignment so the node knows
    /// which rectangle of the fetched partitions to compare.
    pub fn span_of(&self, task_id: u32) -> Option<TaskSpan> {
        self.spans.get(&task_id).copied()
    }

    /// The terminal §3.1 misfit, once a task has proven unplaceable
    /// *and* unsplittable (see [`PlanMisfit`]).
    pub fn misfit(&self) -> Option<&PlanMisfit> {
        self.misfit.as_ref()
    }

    /// Tasks split at run time because every live service rejected
    /// them.
    pub fn runtime_splits(&self) -> u64 {
        self.runtime_splits
    }

    /// Tasks not yet completed (open + in flight).
    pub fn remaining(&self) -> usize {
        self.open.len() + self.in_flight.len()
    }

    /// Tasks waiting on the open list, not yet assigned (the queue
    /// depth `pem stats` reports).
    pub fn queue_depth(&self) -> usize {
        self.open.len()
    }

    /// Tasks currently assigned to a service and not yet reported.
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// Tasks completed exactly once.
    pub fn completed(&self) -> usize {
        self.completed
    }

    /// Tasks the workflow started with.
    pub fn total(&self) -> usize {
        self.total
    }

    /// `true` once every task has completed.
    pub fn is_done(&self) -> bool {
        self.completed == self.total
    }

    /// Assign the next task to `service`, or `None` if the open list is
    /// empty (in-flight tasks may still complete — or fail and reopen)
    /// or the service has been declared dead and not re-added.
    ///
    /// Under [`Policy::Affinity`] the score of a task is the pair
    /// `(cached partitions at the service, replica coverage of its
    /// partitions)`, compared lexicographically: cache locality first,
    /// then — among equally-cached tasks — the one whose partitions the
    /// most data replicas hold, so its fetches can be spread across the
    /// replicated data plane.  Ties go to the oldest task (FIFO).
    pub fn next_task(&mut self, service: ServiceId) -> Option<MatchTask> {
        if self.open.is_empty() || self.dead.contains(&service) {
            return None;
        }
        if !self.tenant_of.is_empty() {
            // v7: more than one tenant may have open work — fairness
            // first (round-robin over tenants), ranking within the
            // chosen tenant.  See the module docs.
            let tenant = self.pick_tenant(service)?;
            return self.next_task_of_tenant(service, tenant);
        }
        // tasks this service rejected as oversize are invisible to it;
        // in the normal case — no rejection anywhere — both policies
        // skip their scans entirely and pop the front in O(1)
        let idx = match self.policy {
            Policy::Fifo => {
                if self.oversize.is_empty() {
                    // nothing is excluded for anyone: plain FIFO pop
                    // instead of an exclusion scan over the open list
                    0
                } else {
                    self.open
                        .iter()
                        .position(|t| !self.rejected_by(t.id, service))?
                }
            }
            Policy::Affinity => {
                let cached = self.cache_status.get(&service);
                let coverage = &self.replica_coverage;
                let no_signal = self.oversize.is_empty()
                    && coverage.is_empty()
                    && match cached {
                        None => true,
                        Some(set) => set.is_empty(),
                    };
                if no_signal {
                    // every score ties at (0, 0) and nothing is
                    // excluded: the oldest task wins — same O(1) pop
                    // as the FIFO fast path
                    let task =
                        self.open.pop_front().expect("checked non-empty");
                    let epoch =
                        self.generation.get(&service).copied().unwrap_or(0);
                    self.in_flight.insert(task.id, (service, epoch, task));
                    self.trace(
                        task.id,
                        TraceEventKind::Assigned,
                        Some(service),
                        None,
                    );
                    return Some(task);
                }
                let score = |t: &MatchTask| -> (usize, u32) {
                    let hits = match cached {
                        None => 0,
                        Some(set) => t
                            .needed_partitions()
                            .iter()
                            .filter(|p| set.contains(p))
                            .count(),
                    };
                    let cov = t
                        .needed_partitions()
                        .iter()
                        .map(|p| coverage.get(p).copied().unwrap_or(0))
                        .sum();
                    (hits, cov)
                };
                // best score wins; ties go to the oldest task (FIFO)
                let mut best: Option<(usize, (usize, u32))> = None;
                for (i, t) in self.open.iter().enumerate() {
                    if self.rejected_by(t.id, service) {
                        continue;
                    }
                    let s = score(t);
                    let better = match &best {
                        None => true,
                        Some((_, best_score)) => s > *best_score,
                    };
                    if better {
                        best = Some((i, s));
                        if s.0 == 2 && coverage.is_empty() {
                            break; // cannot do better than both cached
                        }
                    }
                }
                let (idx, best_score) = best?;
                if best_score.0 > 0 {
                    self.affinity_assignments += 1;
                }
                idx
            }
        };
        let task = self.open.remove(idx).expect("index valid");
        let epoch = self.generation.get(&service).copied().unwrap_or(0);
        self.in_flight.insert(task.id, (service, epoch, task));
        self.trace(task.id, TraceEventKind::Assigned, Some(service), None);
        Some(task)
    }

    /// `true` when `service` has rejected `task` as oversize.
    fn rejected_by(&self, task: u32, service: ServiceId) -> bool {
        self.oversize
            .get(&task)
            .is_some_and(|s| s.contains(&service))
    }

    /// The tenant a task belongs to: runtime-split sub-tasks resolve
    /// through their root; tasks with no tenant entry are the seed
    /// workflow (tenant `0`).
    pub fn tenant_of_task(&self, task_id: u32) -> u32 {
        let root = self.split_parent.get(&task_id).copied().unwrap_or(task_id);
        self.tenant_of.get(&root).copied().unwrap_or(0)
    }

    /// Tasks of `tenant` currently assigned and not yet reported.
    pub fn tenant_inflight(&self, tenant: u32) -> usize {
        self.in_flight
            .keys()
            .filter(|&&id| self.tenant_of_task(id) == tenant)
            .count()
    }

    /// Deficit-round-robin tenant selection: among tenants that have
    /// at least one open task this service may take (not rejected by
    /// it) and that are under their in-flight quota, pick the next one
    /// after the cursor, cyclically.  `None` when no tenant qualifies
    /// (everything open is either excluded for this service or
    /// quota-bound).
    fn pick_tenant(&mut self, service: ServiceId) -> Option<u32> {
        let mut eligible: Vec<u32> = Vec::new();
        for t in self.open.iter() {
            if self.rejected_by(t.id, service) {
                continue;
            }
            let ten = self.tenant_of_task(t.id);
            if !eligible.contains(&ten) {
                eligible.push(ten);
            }
        }
        eligible.retain(|&ten| match self.tenant_quota.get(&ten) {
            Some(&q) => self.tenant_inflight(ten) < q,
            None => true,
        });
        if eligible.is_empty() {
            return None;
        }
        eligible.sort_unstable();
        let next = eligible
            .iter()
            .copied()
            .find(|&t| t > self.rr_last)
            .unwrap_or(eligible[0]);
        self.rr_last = next;
        Some(next)
    }

    /// The [`Self::next_task`] ranking restricted to one tenant's open
    /// tasks: FIFO takes the tenant's oldest eligible task, affinity
    /// scores `(cache hits, replica coverage)` among the tenant's
    /// tasks with FIFO tie-breaks — the same preference order as the
    /// single-tenant path, applied within the tenant.
    fn next_task_of_tenant(
        &mut self,
        service: ServiceId,
        tenant: u32,
    ) -> Option<MatchTask> {
        let idx = {
            let cached = self.cache_status.get(&service);
            let coverage = &self.replica_coverage;
            let mut best: Option<(usize, (usize, u32))> = None;
            for (i, t) in self.open.iter().enumerate() {
                if self.tenant_of_task(t.id) != tenant
                    || self.rejected_by(t.id, service)
                {
                    continue;
                }
                if self.policy == Policy::Fifo {
                    best = Some((i, (0, 0)));
                    break; // oldest eligible task of the tenant
                }
                let hits = match cached {
                    None => 0,
                    Some(set) => t
                        .needed_partitions()
                        .iter()
                        .filter(|p| set.contains(p))
                        .count(),
                };
                let cov = t
                    .needed_partitions()
                    .iter()
                    .map(|p| coverage.get(p).copied().unwrap_or(0))
                    .sum::<u32>();
                let s = (hits, cov);
                let better = match &best {
                    None => true,
                    Some((_, best_score)) => s > *best_score,
                };
                if better {
                    best = Some((i, s));
                    if s.0 == 2 && coverage.is_empty() {
                        break; // cannot do better than both cached
                    }
                }
            }
            let (idx, best_score) = best?;
            if self.policy == Policy::Affinity && best_score.0 > 0 {
                self.affinity_assignments += 1;
            }
            idx
        };
        let task = self.open.remove(idx).expect("index valid");
        let epoch = self.generation.get(&service).copied().unwrap_or(0);
        self.in_flight.insert(task.id, (service, epoch, task));
        self.trace(task.id, TraceEventKind::Assigned, Some(service), None);
        Some(task)
    }

    /// A match service reports that an assigned task's §3.1 memory
    /// footprint exceeds its budget (wire `TaskRejected`, v4): put the
    /// task back on the open list *marked oversize for that service*,
    /// so it is re-offered only to other services.  Subject to the
    /// same freshness rules as [`Self::try_report_complete`] — a
    /// zombie's rejection is dropped (returns `false`).
    ///
    /// When the rejection leaves the task with **no** eligible live
    /// service, re-routing is over: the task is split into sub-tasks
    /// sized to the smallest live budget and those are queued instead
    /// (see the module docs).  If it cannot be split any further, the
    /// typed [`PlanMisfit`] is recorded — "this plan does not fit this
    /// cluster" — and the engines fail fast instead of idling to the
    /// run timeout.
    pub fn reject_task(&mut self, service: ServiceId, task_id: u32) -> bool {
        if self.dead.contains(&service) {
            return false;
        }
        let epoch = self.generation.get(&service).copied().unwrap_or(0);
        let fresh = matches!(
            self.in_flight.get(&task_id),
            Some((s, e, _)) if *s == service && *e == epoch
        );
        if fresh {
            let (_, _, task) = self.in_flight.remove(&task_id).unwrap();
            self.oversize.entry(task_id).or_default().insert(service);
            self.trace(
                task_id,
                TraceEventKind::Rejected,
                Some(service),
                self.split_parent.get(&task_id).copied(),
            );
            if self.rejected_by_every_live_service(task_id) {
                self.reshape_unplaceable(task);
            } else {
                // to the back: every other service sees it soon
                // enough, and the rejecting service's next pull is not
                // dominated by re-ranking the same task it just
                // refused
                self.open.push_back(task);
            }
        }
        fresh
    }

    /// `true` when every service that has joined and not been failed
    /// since has rejected `task_id` as oversize (and at least one such
    /// service exists).  Always `false` while fewer than
    /// [`Self::set_min_split_services`] services have ever joined —
    /// the cluster is still assembling.
    fn rejected_by_every_live_service(&self, task_id: u32) -> bool {
        if self.generation.len() < self.min_split_services {
            return false;
        }
        let Some(rejectors) = self.oversize.get(&task_id) else {
            return false;
        };
        let mut any_live = false;
        for s in self.generation.keys() {
            if self.dead.contains(s) {
                continue;
            }
            any_live = true;
            if !rejectors.contains(s) {
                return false;
            }
        }
        any_live
    }

    /// A task no live service accepts: split its pair space into
    /// sub-tasks that fit the smallest live budget and queue those.
    /// When no finer split exists, record the terminal [`PlanMisfit`]
    /// and leave the task open — the engines fail fast on the misfit,
    /// but a roomier node joining later could still rescue the run.
    fn reshape_unplaceable(&mut self, task: MatchTask) {
        let smallest_budget = self
            .generation
            .keys()
            .filter(|&s| !self.dead.contains(s))
            .filter_map(|s| self.budgets.get(s).copied())
            .min();
        let mem = self.mem_of(task.id);
        // sub-tasks target the smallest live budget; without one on
        // record (defensively — a rejection implies a budget) aim for
        // a quarter of the footprint so repeated splits still converge
        let target = smallest_budget.unwrap_or((mem / 4).max(1));
        if !self.split_task(task, mem, target) {
            let misfit = PlanMisfit {
                task_id: task.id,
                mem_bytes: mem,
                smallest_budget: smallest_budget.unwrap_or(0),
            };
            let tenant = self.tenant_of_task(task.id);
            if tenant != 0 {
                // v7: an unsplittable *tenant* task fails only its
                // tenant — record the per-tenant misfit and drain the
                // tenant's remaining work; the cluster and every other
                // tenant keep running
                self.tenant_misfits.entry(tenant).or_insert(misfit);
                self.open.push_back(task);
                self.drain_tenant(tenant);
                return;
            }
            if self.misfit.is_none() {
                self.misfit = Some(misfit);
            }
            self.open.push_back(task);
        }
    }

    /// Try to split `task` (footprint `mem`) into sub-tasks whose
    /// §3.1 footprints fit `budget`, queueing them.  Returns `false`
    /// when no finer split exists: the plan carried no sizes for the
    /// task, a single pair already exceeds the budget, or the pair
    /// space is down to one cell.
    fn split_task(&mut self, task: MatchTask, mem: u64, budget: u64) -> bool {
        let Some(&(l_len, r_len)) = self.sizes.get(&task.id) else {
            return false; // no split metadata (plan-less run)
        };
        if mem == 0 || l_len == 0 || r_len == 0 {
            return false;
        }
        // §3.1: mem = c_ms · m₁ · m₂ — recover the per-cell cost, then
        // the largest pair-space rectangle the budget allows
        let per_cell = mem
            .div_ceil(l_len as u64 * r_len as u64)
            .max(1);
        let max_cells = budget / per_cell;
        if max_cells == 0 {
            return false; // a single pair exceeds the budget
        }
        let span = self.spans.get(&task.id).copied().unwrap_or(TaskSpan {
            left: (0, l_len),
            right: (0, r_len),
        });
        let triangle =
            task.left == task.right && span.left == span.right;
        // (span, left entities, right entities) per sub-task
        let mut children: Vec<(TaskSpan, u32, u32)> = Vec::new();
        if triangle {
            if l_len < 2 {
                return false; // a 1-entity triangle has no pairs left
            }
            // chunk width: the rectangles between chunks are the
            // largest sub-tasks (≤ c² cells); at least 2 chunks so a
            // forced split always makes progress
            let c = ((max_cells as f64).sqrt().floor() as u32)
                .clamp(1, l_len);
            let k = (l_len.div_ceil(c) as usize).max(2);
            let chunks = chunk_ranges(span.left.0, span.left.1, k);
            for (i, &a) in chunks.iter().enumerate() {
                children.push((
                    TaskSpan { left: a, right: a },
                    a.1 - a.0,
                    a.1 - a.0,
                ));
                for &b in chunks.iter().skip(i + 1) {
                    children.push((
                        TaskSpan { left: a, right: b },
                        a.1 - a.0,
                        b.1 - b.0,
                    ));
                }
            }
        } else {
            // rectangle: a grid of balanced chunks, ≤ c₁ × c₂ cells
            let c1 = ((max_cells as f64).sqrt().floor() as u32)
                .clamp(1, l_len);
            let c2 = (((max_cells / c1 as u64).max(1)) as u32)
                .clamp(1, r_len);
            let mut k1 = l_len.div_ceil(c1) as usize;
            let mut k2 = r_len.div_ceil(c2) as usize;
            if k1 == 1 && k2 == 1 {
                // the whole rectangle "fits" yet every live service
                // rejected it (budget drift): force a halving along
                // the longer side so the split still makes progress
                if l_len >= r_len && l_len >= 2 {
                    k1 = 2;
                } else if r_len >= 2 {
                    k2 = 2;
                } else {
                    return false; // a 1×1 cell: nothing left to split
                }
            }
            let ls = chunk_ranges(span.left.0, span.left.1, k1);
            let rs = chunk_ranges(span.right.0, span.right.1, k2);
            for &a in &ls {
                for &b in &rs {
                    children.push((
                        TaskSpan { left: a, right: b },
                        a.1 - a.0,
                        b.1 - b.0,
                    ));
                }
            }
        }
        // bookkeeping: children adopt the original plan task's root,
        // so completion accounting merges the whole tree exactly once
        let root = self.split_parent.remove(&task.id).unwrap_or(task.id);
        self.trace(
            task.id,
            TraceEventKind::Split,
            None,
            (task.id != root).then_some(root),
        );
        let n = children.len();
        match self.split_outstanding.get_mut(&root) {
            // splitting a sub-task: it is replaced by its children
            Some(left) => *left += n - 1,
            None => {
                self.split_outstanding.insert(root, n);
            }
        }
        self.spans.remove(&task.id);
        self.sizes.remove(&task.id);
        if task.id != root {
            self.mem.remove(&task.id);
        }
        self.oversize.remove(&task.id);
        for (span, cl, cr) in children {
            let id = self.next_split_id;
            self.next_split_id += 1;
            self.split_parent.insert(id, root);
            self.spans.insert(id, span);
            self.sizes.insert(id, (cl, cr));
            self.mem.insert(id, per_cell * cl as u64 * cr as u64);
            self.open.push_back(MatchTask {
                id,
                left: task.left,
                right: task.right,
            });
            self.trace(id, TraceEventKind::Queued, None, Some(root));
        }
        self.runtime_splits += 1;
        true
    }

    /// Re-check every oversize-marked open task after the live set
    /// shrank ([`Self::fail_service`]): one that is now rejected by
    /// every remaining live service would never be pulled again — a
    /// silent stall — so it is reshaped (or declared a misfit) now.
    fn resolve_unplaceable_open(&mut self) {
        let stuck: Vec<u32> = self
            .oversize
            .keys()
            .copied()
            .filter(|id| self.rejected_by_every_live_service(*id))
            .collect();
        for id in stuck {
            let Some(pos) = self.open.iter().position(|t| t.id == id)
            else {
                continue; // in flight elsewhere — not stalled
            };
            let task = self.open.remove(pos).expect("position valid");
            self.reshape_unplaceable(task);
        }
    }

    /// Tasks at least one service has rejected as oversize.
    pub fn oversize_tasks(&self) -> usize {
        self.oversize.len()
    }

    /// Assign up to `max` tasks to `service` in one call (the v3
    /// batched pull).  Each pick re-ranks the remaining open list, so
    /// the affinity / replica-coverage preference of
    /// [`Scheduler::next_task`] orders tasks *within* the batch too.
    /// Returns fewer than `max` (possibly none) when the open list
    /// runs dry or the service is dead.
    pub fn next_tasks_for(
        &mut self,
        service: ServiceId,
        max: usize,
    ) -> Vec<MatchTask> {
        let mut batch = Vec::with_capacity(max.min(self.open.len()));
        for _ in 0..max {
            match self.next_task(service) {
                Some(task) => batch.push(task),
                None => break,
            }
        }
        batch
    }

    /// A data replica announced that it holds `parts`: bump each
    /// partition's replica count.  Called once per announced replica
    /// (the workflow service deduplicates re-announcements).
    pub fn add_replica_coverage(&mut self, parts: &[PartitionId]) {
        for p in parts {
            *self.replica_coverage.entry(*p).or_insert(0) += 1;
        }
    }

    /// How many data replicas hold `p`, as announced so far.
    pub fn replica_coverage(&self, p: PartitionId) -> u32 {
        self.replica_coverage.get(&p).copied().unwrap_or(0)
    }

    /// A match service reports a completed task together with its current
    /// cache content (piggybacked status, paper §4).
    pub fn report_complete(
        &mut self,
        service: ServiceId,
        task_id: u32,
        cached: Vec<PartitionId>,
    ) {
        assert!(
            self.try_report_complete(service, task_id, cached),
            "completion for task {task_id} not in flight at {service:?}"
        );
    }

    /// Like [`Self::report_complete`], but tolerates reports that no
    /// longer match the in-flight table: a service that was presumed dead
    /// (missed heartbeats → [`Self::fail_service`]) may still deliver a
    /// completion for a task that has since been re-queued or re-assigned.
    /// The distributed runtime must not crash on such stragglers — the
    /// stale report is dropped and `false` returned.
    ///
    /// A report is **fresh** only when all three hold: the service has
    /// not been declared dead, the task is in flight at that service,
    /// and the assignment was made in the service's *current*
    /// generation.  The generation check is what stops the
    /// double-completion: without it, a zombie's straggler could
    /// satisfy a post-failure re-assignment of the same task.  The
    /// cache status is recorded only for live services.
    pub fn try_report_complete(
        &mut self,
        service: ServiceId,
        task_id: u32,
        cached: Vec<PartitionId>,
    ) -> bool {
        if self.dead.contains(&service) {
            return false;
        }
        let fresh = self.try_complete_batched(service, task_id);
        self.cache_status
            .insert(service, cached.into_iter().collect());
        fresh
    }

    /// Like [`Self::try_report_complete`] but leaves the service's
    /// recorded cache status untouched: the v3 batch path folds many
    /// completions with this and then records the batch's piggybacked
    /// status once via [`Self::record_cache_status`], instead of
    /// rebuilding the status set per task.
    pub fn try_complete_batched(
        &mut self,
        service: ServiceId,
        task_id: u32,
    ) -> bool {
        if self.dead.contains(&service) {
            return false;
        }
        let epoch = self.generation.get(&service).copied().unwrap_or(0);
        let fresh = matches!(
            self.in_flight.get(&task_id),
            Some((s, e, _)) if *s == service && *e == epoch
        );
        if fresh {
            self.in_flight.remove(&task_id);
            // a completed task's oversize marks are dead weight — and
            // would needlessly keep the pull fast path disabled
            self.oversize.remove(&task_id);
            match self.split_parent.remove(&task_id) {
                // a runtime-split sub-task: the root counts as
                // completed exactly once, when its last descendant
                // reports — never before, never twice
                Some(root) => {
                    self.spans.remove(&task_id);
                    self.sizes.remove(&task_id);
                    self.mem.remove(&task_id);
                    self.trace(
                        task_id,
                        TraceEventKind::SpanMerged,
                        Some(service),
                        Some(root),
                    );
                    let outstanding = self
                        .split_outstanding
                        .get_mut(&root)
                        .expect("split root tracked");
                    *outstanding -= 1;
                    if *outstanding == 0 {
                        self.split_outstanding.remove(&root);
                        self.completed += 1;
                        self.note_tenant_completion(root);
                        self.trace(
                            root,
                            TraceEventKind::Completed,
                            Some(service),
                            None,
                        );
                    }
                }
                None => {
                    self.completed += 1;
                    self.note_tenant_completion(task_id);
                    self.trace(
                        task_id,
                        TraceEventKind::Completed,
                        Some(service),
                        None,
                    );
                }
            }
        }
        fresh
    }

    /// Record a service's piggybacked cache status without reporting a
    /// completion.  The v3 batch path sends the status **once per
    /// batch**, so the workflow service folds the batch's completions
    /// with [`Self::try_report_complete`] (empty status) and records
    /// the real status here, instead of rebuilding the status set per
    /// task.  Dead services are ignored.
    pub fn record_cache_status(
        &mut self,
        service: ServiceId,
        cached: Vec<PartitionId>,
    ) {
        if self.dead.contains(&service) {
            return;
        }
        self.cache_status
            .insert(service, cached.into_iter().collect());
    }

    /// A match service was added (paper §4: services can be added on
    /// demand — pull scheduling needs no state, this just primes the
    /// cache-status entry).  Also the only way a previously-failed
    /// [`ServiceId`] becomes assignable again — an explicit re-join,
    /// starting a fresh generation.
    pub fn add_service(&mut self, service: ServiceId) {
        self.dead.remove(&service);
        self.generation.entry(service).or_insert(0);
        self.cache_status.entry(service).or_default();
    }

    /// `true` when `service` was failed and has not re-joined since.
    pub fn is_dead(&self, service: ServiceId) -> bool {
        self.dead.contains(&service)
    }

    /// A match service failed or was removed: requeue its in-flight
    /// tasks (at the front — they are oldest), drop its cache status
    /// and budget, bump its generation and mark it dead (see the
    /// module docs on the generation check).  Returns the number of
    /// requeued tasks.
    pub fn fail_service(&mut self, service: ServiceId) -> usize {
        let failed: Vec<u32> = self
            .in_flight
            .iter()
            .filter(|(_, (s, _, _))| *s == service)
            .map(|(id, _)| *id)
            .collect();
        for id in &failed {
            let (_, _, task) = self.in_flight.remove(id).unwrap();
            self.open.push_front(task);
            self.trace(
                *id,
                TraceEventKind::Requeued,
                Some(service),
                self.split_parent.get(id).copied(),
            );
        }
        self.cache_status.remove(&service);
        self.budgets.remove(&service);
        *self.generation.entry(service).or_insert(0) += 1;
        self.dead.insert(service);
        // the live set shrank: an oversize task now rejected by every
        // surviving service would otherwise sit unpullable forever
        self.resolve_unplaceable_open();
        failed.len()
    }

    /// Known cache status (for tests / introspection).
    pub fn cached_at(&self, service: ServiceId) -> Option<&HashSet<PartitionId>> {
        self.cache_status.get(&service)
    }

    // ------------------------------------------------- tenants (v7)

    /// Bump the completed count of the tenant owning root task `root`
    /// (tenant 0, the seed workflow, is tracked by the global
    /// counters only).
    fn note_tenant_completion(&mut self, root: u32) {
        if let Some(&tenant) = self.tenant_of.get(&root) {
            *self.tenant_completed.entry(tenant).or_insert(0) += 1;
        }
    }

    /// Reserve `count` task ids above everything the scheduler has
    /// ever issued (plan tasks *and* runtime-split sub-tasks) and
    /// return the first.  A submitted plan's tasks are renumbered into
    /// this range before [`Self::add_tenant_tasks`], so tenants can
    /// never collide with the seed workflow or with each other.
    pub fn reserve_task_ids(&mut self, count: u32) -> u32 {
        let base = self.next_split_id;
        self.next_split_id += count;
        base
    }

    /// Admit a submitted plan's tasks as tenant `tenant` (> 0): the
    /// tasks join the open list with their §3.1 footprints and split
    /// metadata merged in, and `quota` (if any) caps how many of the
    /// tenant's tasks may be in flight at once.  Task ids must come
    /// from [`Self::reserve_task_ids`]; partition-id namespacing is
    /// the caller's concern ([`crate::service::WorkflowServiceServer`]
    /// offsets them into the shared data service).
    pub fn add_tenant_tasks(
        &mut self,
        tenant: u32,
        tasks: Vec<MatchTask>,
        mem: HashMap<u32, u64>,
        sizes: HashMap<u32, (u32, u32)>,
        quota: Option<usize>,
    ) {
        debug_assert!(tenant != 0, "tenant 0 is the seed workflow");
        self.total += tasks.len();
        self.tenant_total.insert(tenant, tasks.len());
        self.tenant_completed.insert(tenant, 0);
        if let Some(q) = quota {
            self.tenant_quota.insert(tenant, q.max(1));
        }
        self.mem.extend(mem);
        self.sizes.extend(sizes);
        for t in tasks {
            self.tenant_of.insert(t.id, tenant);
            self.trace(t.id, TraceEventKind::Planned, None, None);
            self.trace(t.id, TraceEventKind::Queued, None, None);
            self.open.push_back(t);
        }
    }

    /// Remove every remaining task of `tenant` — open *and* in flight
    /// (stragglers completing a drained task are dropped as stale by
    /// the generation-checked report paths).  Called when the
    /// submitting client vanishes (abort) or the tenant misfits; the
    /// global totals shrink by the tenant's unfinished tasks so
    /// [`Self::is_done`] still converges.  Returns the number of
    /// tasks dropped.  Tenant 0 (the seed workflow) cannot be
    /// drained.
    pub fn drain_tenant(&mut self, tenant: u32) -> usize {
        if tenant == 0 {
            return 0;
        }
        let open_drop: Vec<u32> = self
            .open
            .iter()
            .map(|t| t.id)
            .filter(|&id| self.tenant_of_task(id) == tenant)
            .collect();
        let flight_drop: Vec<u32> = self
            .in_flight
            .keys()
            .copied()
            .filter(|&id| self.tenant_of_task(id) == tenant)
            .collect();
        let dropped = open_drop.len() + flight_drop.len();
        let drop_set: HashSet<u32> =
            open_drop.iter().copied().collect();
        self.open.retain(|t| !drop_set.contains(&t.id));
        for id in open_drop.into_iter().chain(flight_drop) {
            self.in_flight.remove(&id);
            self.split_parent.remove(&id);
            self.spans.remove(&id);
            self.sizes.remove(&id);
            self.mem.remove(&id);
            self.oversize.remove(&id);
        }
        // root-level bookkeeping of the tenant's plan tasks
        let roots: Vec<u32> = self
            .tenant_of
            .iter()
            .filter(|(_, &t)| t == tenant)
            .map(|(&r, _)| r)
            .collect();
        for r in &roots {
            self.split_outstanding.remove(r);
            self.sizes.remove(r);
            self.mem.remove(r);
            self.oversize.remove(r);
            self.tenant_of.remove(r);
        }
        let done = self.tenant_completed.get(&tenant).copied().unwrap_or(0);
        let tot = self.tenant_total.get(&tenant).copied().unwrap_or(0);
        self.total -= tot.saturating_sub(done);
        self.tenant_quota.remove(&tenant);
        dropped
    }

    /// `(completed, total)` root tasks of a tenant.  `(0, 0)` for
    /// unknown tenants (and for tenant 0 — the seed workflow reads
    /// the global [`Self::completed`] / [`Self::total`]).
    pub fn tenant_progress(&self, tenant: u32) -> (usize, usize) {
        (
            self.tenant_completed.get(&tenant).copied().unwrap_or(0),
            self.tenant_total.get(&tenant).copied().unwrap_or(0),
        )
    }

    /// The per-tenant §3.1 misfit, if the tenant's plan proved
    /// unplaceable on this cluster (its tasks have been drained).
    pub fn tenant_misfit(&self, tenant: u32) -> Option<&PlanMisfit> {
        self.tenant_misfits.get(&tenant)
    }

    /// Aggregate §3.1 capacity of the live cluster: the sum of the
    /// join-time budgets of every live service, `None` when at least
    /// one live service reported no budget (unlimited ⇒ unbounded
    /// capacity), and `Some(0)` when no live service exists.  The
    /// admission-control input for submitted plans.
    pub fn cluster_budget(&self) -> Option<u64> {
        let mut sum = 0u64;
        let mut any = false;
        for s in self.generation.keys() {
            if self.dead.contains(s) {
                continue;
            }
            any = true;
            match self.budgets.get(s) {
                Some(b) => sum = sum.saturating_add(*b),
                None => return None,
            }
        }
        if !any {
            return Some(0);
        }
        Some(sum)
    }
}

/// `k` balanced contiguous half-open ranges covering `[lo, hi)` —
/// sizes differ by at most one, like §3.2's even block splitting.
/// Requires `1 <= k <= hi - lo`.
fn chunk_ranges(lo: u32, hi: u32, k: usize) -> Vec<(u32, u32)> {
    let n = (hi - lo) as usize;
    debug_assert!(k >= 1 && k <= n, "chunk_ranges({lo}, {hi}, {k})");
    let base = (n / k) as u32;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = lo;
    for i in 0..k {
        let len = base + u32::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, hi);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn task(id: u32, l: u32, r: u32) -> MatchTask {
        MatchTask {
            id,
            left: PartitionId(l),
            right: PartitionId(r),
        }
    }

    #[test]
    fn fifo_order() {
        let mut s =
            Scheduler::new(vec![task(0, 0, 0), task(1, 1, 1)], Policy::Fifo);
        assert_eq!(s.next_task(ServiceId(0)).unwrap().id, 0);
        assert_eq!(s.next_task(ServiceId(1)).unwrap().id, 1);
        assert!(s.next_task(ServiceId(0)).is_none());
        assert_eq!(s.remaining(), 2);
        s.report_complete(ServiceId(0), 0, vec![PartitionId(0)]);
        s.report_complete(ServiceId(1), 1, vec![PartitionId(1)]);
        assert!(s.is_done());
    }

    #[test]
    fn affinity_prefers_cached_partitions() {
        let tasks = vec![task(0, 0, 1), task(1, 2, 3), task(2, 2, 2)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        // service 0 reports partitions 2,3 cached after its first task
        let t0 = s.next_task(ServiceId(0)).unwrap(); // FIFO first: task 0
        assert_eq!(t0.id, 0);
        s.report_complete(
            ServiceId(0),
            0,
            vec![PartitionId(2), PartitionId(3)],
        );
        // next assignment should pick task 1 (both partitions cached)
        let t1 = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t1.id, 1);
        assert_eq!(s.affinity_assignments, 1);
    }

    #[test]
    fn affinity_falls_back_to_fifo_without_status() {
        let mut s = Scheduler::new(
            vec![task(0, 0, 0), task(1, 1, 1)],
            Policy::Affinity,
        );
        assert_eq!(s.next_task(ServiceId(5)).unwrap().id, 0);
        assert_eq!(s.affinity_assignments, 0);
    }

    #[test]
    fn failure_requeues_in_flight() {
        let mut s = Scheduler::new(
            vec![task(0, 0, 0), task(1, 1, 1), task(2, 2, 2)],
            Policy::Fifo,
        );
        let a = s.next_task(ServiceId(0)).unwrap();
        let _b = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(s.fail_service(ServiceId(0)), 1);
        // the failed task is back at the front
        let re = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(re.id, a.id);
        // completing everything still reaches done
        s.report_complete(ServiceId(1), 1, vec![]);
        s.report_complete(ServiceId(1), 0, vec![]);
        let c = s.next_task(ServiceId(1)).unwrap();
        s.report_complete(ServiceId(1), c.id, vec![]);
        assert!(s.is_done());
    }

    #[test]
    #[should_panic]
    fn wrong_service_completion_panics() {
        let mut s = Scheduler::new(vec![task(0, 0, 0)], Policy::Fifo);
        let _ = s.next_task(ServiceId(0)).unwrap();
        s.report_complete(ServiceId(1), 0, vec![]);
    }

    /// Property: under any interleaving of assignment/completion/failure
    /// (every failed node re-joining under a fresh id, as the wire
    /// layer guarantees), every task is eventually completed exactly
    /// once.
    #[test]
    fn prop_all_tasks_complete_exactly_once() {
        forall("scheduler-complete", 80, |rng| {
            let n_tasks = 1 + rng.gen_range(60);
            let n_services = 1 + rng.gen_range(5);
            let tasks: Vec<MatchTask> = (0..n_tasks as u32)
                .map(|i| task(i, i % 7, (i * 3) % 7))
                .collect();
            let policy = if rng.gen_bool(0.5) {
                Policy::Affinity
            } else {
                Policy::Fifo
            };
            let mut s = Scheduler::new(tasks, policy);
            // slot → the ServiceId currently joined for that node; a
            // failed node re-joins under a fresh id (like the wire
            // layer, which never reuses ids)
            let mut ids: Vec<usize> = (0..n_services).collect();
            let mut next_id = n_services;
            for &id in &ids {
                s.add_service(ServiceId(id));
            }
            let mut holding: Vec<Vec<MatchTask>> =
                vec![Vec::new(); n_services];
            let mut completions: Vec<u32> = Vec::new();
            let mut failures = 0;
            while !s.is_done() {
                let svc = rng.gen_range(n_services);
                match rng.gen_range(10) {
                    // occasionally fail a service (max 3 times per run)
                    0 if failures < 3 && !holding[svc].is_empty() => {
                        let old = ServiceId(ids[svc]);
                        s.fail_service(old);
                        holding[svc].clear();
                        failures += 1;
                        // the dead id is out of the game for good
                        assert!(s.next_task(old).is_none());
                        // re-join under a fresh id
                        ids[svc] = next_id;
                        next_id += 1;
                        s.add_service(ServiceId(ids[svc]));
                    }
                    // complete something it holds
                    1..=5 if !holding[svc].is_empty() => {
                        let t = holding[svc].pop().unwrap();
                        s.report_complete(
                            ServiceId(ids[svc]),
                            t.id,
                            t.needed_partitions(),
                        );
                        completions.push(t.id);
                    }
                    // otherwise pull a new task
                    _ => {
                        if let Some(t) =
                            s.next_task(ServiceId(ids[svc]))
                        {
                            holding[svc].push(t);
                        } else if holding.iter().all(Vec::is_empty) {
                            // nothing open and nothing held anywhere,
                            // but not done? impossible — fail loudly.
                            assert!(
                                s.is_done(),
                                "deadlock: open empty, nothing held"
                            );
                        }
                    }
                }
            }
            completions.sort_unstable();
            completions.dedup();
            assert_eq!(completions.len(), n_tasks, "each task once");
        });
    }

    /// With equal cache affinity (here: none), assignment prefers the
    /// task whose partitions are held by the most data replicas.
    #[test]
    fn replica_coverage_breaks_affinity_ties() {
        let tasks = vec![task(0, 0, 1), task(1, 2, 3)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        // two replicas announced holding partitions 2 and 3; only one
        // holds 0 and 1
        s.add_replica_coverage(&[
            PartitionId(0),
            PartitionId(1),
            PartitionId(2),
            PartitionId(3),
        ]);
        s.add_replica_coverage(&[PartitionId(2), PartitionId(3)]);
        assert_eq!(s.replica_coverage(PartitionId(2)), 2);
        assert_eq!(s.replica_coverage(PartitionId(0)), 1);
        assert_eq!(s.replica_coverage(PartitionId(99)), 0);
        // no cache status → cache score ties at 0 → coverage decides
        let t = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t.id, 1, "widely-replicated task preferred");
        // coverage alone is not an affinity (cache) hit
        assert_eq!(s.affinity_assignments, 0);
    }

    /// Cache affinity still dominates replica coverage: a task cached
    /// at the service wins even when another task is better replicated.
    #[test]
    fn cache_affinity_dominates_replica_coverage() {
        let tasks = vec![task(0, 9, 9), task(1, 5, 6), task(2, 2, 3)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        // no status, no coverage yet → plain FIFO for the first pull
        let t0 = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t0.id, 0);
        s.report_complete(ServiceId(0), 0, vec![PartitionId(5)]);
        // three replicas announce partitions 2 and 3 (task 2's pair)
        for _ in 0..3 {
            s.add_replica_coverage(&[PartitionId(2), PartitionId(3)]);
        }
        // task 1 has one cached partition; task 2 has 3× coverage but
        // nothing cached — cache locality must win
        assert_eq!(s.next_task(ServiceId(0)).unwrap().id, 1);
        assert_eq!(s.affinity_assignments, 1);
    }

    #[test]
    fn add_service_primes_status() {
        let mut s = Scheduler::new(vec![task(0, 0, 0)], Policy::Affinity);
        s.add_service(ServiceId(3));
        assert!(s.cached_at(ServiceId(3)).unwrap().is_empty());
    }

    #[test]
    fn affinity_tie_breaks_to_oldest_task() {
        // tasks 1 and 2 both score one cached partition; the tie must go
        // to the older (lower-index) task, i.e. FIFO within a score class
        let tasks = vec![task(0, 8, 9), task(1, 5, 6), task(2, 5, 7)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        let t0 = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t0.id, 0);
        s.report_complete(ServiceId(0), 0, vec![PartitionId(5)]);
        let t = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t.id, 1, "tie between 1 and 2 must pick the older");
        assert_eq!(s.affinity_assignments, 1);
    }

    #[test]
    fn affinity_double_hit_beats_single_hit() {
        // task 2 has both partitions cached and must win over task 1
        // (one cached) even though task 1 is older
        let tasks = vec![task(0, 9, 9), task(1, 2, 8), task(2, 2, 3)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        let t0 = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(t0.id, 0);
        s.report_complete(
            ServiceId(1),
            0,
            vec![PartitionId(2), PartitionId(3)],
        );
        assert_eq!(s.next_task(ServiceId(1)).unwrap().id, 2);
    }

    #[test]
    fn affinity_zero_scores_fall_back_to_fifo_order() {
        let tasks = vec![task(0, 1, 2), task(1, 3, 4)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        let t0 = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t0.id, 0);
        s.report_complete(ServiceId(0), 0, vec![PartitionId(99)]);
        // nothing cached matches the remaining task: FIFO, no affinity hit
        assert_eq!(s.next_task(ServiceId(0)).unwrap().id, 1);
        assert_eq!(s.affinity_assignments, 0);
    }

    #[test]
    fn fail_service_requeues_all_in_flight_and_drops_status() {
        let tasks =
            vec![task(0, 0, 0), task(1, 1, 1), task(2, 2, 2), task(3, 3, 3)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        // service 0 holds tasks 0 and 1, service 1 holds task 2
        let a0 = s.next_task(ServiceId(0)).unwrap();
        let a1 = s.next_task(ServiceId(0)).unwrap();
        let b = s.next_task(ServiceId(1)).unwrap();
        s.report_complete(ServiceId(1), b.id, vec![PartitionId(2)]);
        assert_eq!(s.fail_service(ServiceId(0)), 2);
        assert!(s.cached_at(ServiceId(0)).is_none(), "status dropped");
        // the dead service's tasks are at the front of the open list and
        // the workflow still completes through the surviving service
        let ids: Vec<u32> = std::iter::from_fn(|| s.next_task(ServiceId(1)))
            .map(|t| t.id)
            .collect();
        assert_eq!(ids.len(), 3);
        // re-queued tasks go to the front, ahead of the never-assigned
        // task 3 (their mutual order depends on in-flight iteration)
        let front: std::collections::HashSet<u32> =
            ids[..2].iter().copied().collect();
        assert_eq!(
            front,
            [a0.id, a1.id].into_iter().collect(),
            "failed tasks re-queued at the front"
        );
        assert_eq!(ids[2], 3);
        for id in &ids {
            s.report_complete(ServiceId(1), *id, vec![]);
        }
        assert!(s.is_done());
        assert_eq!(s.completed(), 4);
    }

    /// The PR-3 bugfix, reproduced: before the generation check, a
    /// service declared dead could keep pulling (the wire layer's old
    /// `touch` silently resurrected it), be handed the re-queued copy
    /// of its *own* in-flight task, and its straggler report from the
    /// first assignment then completed the second one — the workflow
    /// could finish while the re-execution was still running, and the
    /// "dead" node kept computing against a task the scheduler had
    /// re-opened.  Now the dead id is fenced until an explicit
    /// re-join.
    #[test]
    fn resurrected_service_cannot_pull_or_complete() {
        let mut s = Scheduler::new(
            vec![task(0, 0, 0), task(1, 1, 1)],
            Policy::Fifo,
        );
        s.add_service(ServiceId(0));
        let t = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(s.fail_service(ServiceId(0)), 1);
        assert!(s.is_dead(ServiceId(0)));
        // the zombie pulls again: with the old code this handed task 0
        // back to the dead id — now it gets nothing
        assert!(s.next_task(ServiceId(0)).is_none());
        // and its straggler completion is dropped
        assert!(!s.try_report_complete(ServiceId(0), t.id, vec![]));
        assert_eq!(s.completed(), 0);
        // the re-queued task completes exactly once at a live service
        s.add_service(ServiceId(1));
        let re = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(re.id, t.id);
        assert!(s.try_report_complete(ServiceId(1), re.id, vec![]));
        // an explicit re-join revives the old id in a new generation:
        // it can work again, but nothing from before the failure counts
        s.add_service(ServiceId(0));
        assert!(!s.is_dead(ServiceId(0)));
        assert!(!s.try_report_complete(ServiceId(0), t.id, vec![]));
        let t1 = s.next_task(ServiceId(0)).unwrap();
        assert!(s.try_report_complete(ServiceId(0), t1.id, vec![]));
        assert!(s.is_done());
        assert_eq!(s.completed(), 2);
    }

    /// Batched assignment keeps the affinity ordering *within* a
    /// batch: with partitions 5/6 cached, both tasks touching them
    /// come first, best score first, before the cold task.
    #[test]
    fn next_tasks_for_orders_batch_by_affinity() {
        let tasks = vec![
            task(0, 9, 9),
            task(1, 7, 8),
            task(2, 5, 7),
            task(3, 5, 6),
        ];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        s.add_service(ServiceId(0));
        let t0 = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t0.id, 0);
        s.report_complete(
            ServiceId(0),
            0,
            vec![PartitionId(5), PartitionId(6)],
        );
        let batch = s.next_tasks_for(ServiceId(0), 3);
        assert_eq!(
            batch.iter().map(|t| t.id).collect::<Vec<_>>(),
            vec![3, 2, 1],
            "both-cached, then one-cached, then cold"
        );
        // a further pull drains nothing: the open list is empty
        assert!(s.next_tasks_for(ServiceId(0), 4).is_empty());
        // dead services get empty batches
        s.fail_service(ServiceId(0));
        assert!(s.next_tasks_for(ServiceId(0), 4).is_empty());
    }

    /// §3.1 memory-model parity: a rejected-oversize task is re-queued
    /// and re-routed to other services, but never re-offered to the
    /// service that rejected it.
    #[test]
    fn oversize_rejection_requeues_and_excludes_the_rejector() {
        let mut s = Scheduler::new(
            vec![task(0, 0, 0), task(1, 1, 1)],
            Policy::Fifo,
        );
        s.add_service(ServiceId(0));
        s.add_service(ServiceId(1));
        let t = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t.id, 0);
        assert!(s.reject_task(ServiceId(0), t.id), "fresh rejection");
        assert_eq!(s.oversize_tasks(), 1);
        assert_eq!(s.remaining(), 2, "nothing lost");
        // a duplicate rejection of the same task is stale
        assert!(!s.reject_task(ServiceId(0), t.id));
        // the rejector only ever sees the other task again
        let n = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(n.id, 1);
        assert!(s.next_task(ServiceId(0)).is_none(), "task 0 invisible");
        // another service picks the oversize task up
        let re = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(re.id, 0);
        s.report_complete(ServiceId(1), re.id, vec![]);
        s.report_complete(ServiceId(0), n.id, vec![]);
        assert!(s.is_done());
    }

    /// A task rejected by every service *without* split metadata (a
    /// plan-less run) raises the typed [`PlanMisfit`] — the fail-fast
    /// signal — while the task itself stays open, so a roomier late
    /// joiner can still rescue the run.
    #[test]
    fn task_rejected_by_all_services_raises_misfit_but_stays_open() {
        let mut s = Scheduler::new(vec![task(0, 0, 0)], Policy::Affinity);
        for id in 0..2 {
            s.add_service(ServiceId(id));
        }
        for id in 0..2 {
            let t = s.next_task(ServiceId(id)).unwrap();
            assert_eq!(t.id, 0);
            assert!(s.reject_task(ServiceId(id), t.id));
        }
        // no sizes were attached: splitting is impossible — the typed
        // error is raised instead of letting the run idle to timeout
        let misfit = s.misfit().expect("misfit raised").clone();
        assert_eq!(misfit.task_id, 0);
        assert!(misfit.to_string().contains("does not fit"));
        assert_eq!(s.runtime_splits(), 0);
        assert!(s.next_task(ServiceId(0)).is_none());
        assert!(s.next_task(ServiceId(1)).is_none());
        assert_eq!(s.remaining(), 1);
        assert!(!s.is_done());
        // a fresh service (bigger budget) can still complete it
        s.add_service(ServiceId(2));
        let t = s.next_task(ServiceId(2)).unwrap();
        assert!(s.try_report_complete(ServiceId(2), t.id, vec![]));
        assert!(s.is_done());
    }

    /// Runtime splitting (the tentpole): an intra-partition task every
    /// live service rejects is split into triangle + rectangle
    /// sub-tasks sized to the smallest live budget, the sub-tasks tile
    /// the parent pair space exactly, and completing them all counts
    /// the parent as completed exactly once.
    #[test]
    fn all_rejected_intra_task_splits_into_fitting_subtasks() {
        let mut s = Scheduler::new(vec![task(0, 7, 7)], Policy::Fifo);
        // §3.1 metadata: 30×30 entities at 20 B per pair
        let mem = 20u64 * 30 * 30;
        s.set_task_meta(
            [(0u32, mem)].into_iter().collect(),
            [(0u32, (30u32, 30u32))].into_iter().collect(),
        );
        for id in 0..2 {
            s.add_service(ServiceId(id));
            s.set_service_budget(ServiceId(id), Some(20 * 15 * 15));
        }
        for id in 0..2 {
            let t = s.next_task(ServiceId(id)).unwrap();
            assert_eq!(t.id, 0);
            assert!(s.reject_task(ServiceId(id), t.id));
        }
        assert_eq!(s.runtime_splits(), 1);
        assert!(s.misfit().is_none());
        assert_eq!(s.oversize_tasks(), 0, "parent left circulation");
        // 2 chunks of 15 → 2 triangles + 1 rectangle, every footprint
        // within the smallest live budget
        assert_eq!(s.remaining(), 3);
        let mut spans = Vec::new();
        let mut pulled = Vec::new();
        for _ in 0..3 {
            let t = s.next_task(ServiceId(0)).unwrap();
            assert!(t.id >= 1, "sub-task ids sit above the plan's");
            assert_eq!(t.left, PartitionId(7));
            assert_eq!(t.right, PartitionId(7));
            assert!(s.mem_of(t.id) <= 20 * 15 * 15);
            spans.push(s.span_of(t.id).expect("sub-tasks carry spans"));
            pulled.push(t.id);
        }
        // exact tiling of the 30-entity triangle
        assert!(spans.contains(&TaskSpan {
            left: (0, 15),
            right: (0, 15),
        }));
        assert!(spans.contains(&TaskSpan {
            left: (15, 30),
            right: (15, 30),
        }));
        assert!(spans.contains(&TaskSpan {
            left: (0, 15),
            right: (15, 30),
        }));
        // completing two children completes nothing yet…
        assert!(s.try_report_complete(ServiceId(0), pulled[0], vec![]));
        assert!(s.try_report_complete(ServiceId(0), pulled[1], vec![]));
        assert_eq!(s.completed(), 0);
        assert!(!s.is_done());
        // …the last one completes the parent exactly once
        assert!(s.try_report_complete(ServiceId(0), pulled[2], vec![]));
        assert_eq!(s.completed(), 1);
        assert!(s.is_done());
        // a straggler duplicate of a child is dropped
        assert!(!s.try_report_complete(ServiceId(0), pulled[2], vec![]));
        assert_eq!(s.completed(), 1);
    }

    /// A cross-partition task splits into a balanced grid of
    /// rectangles whose cells tile the parent exactly.
    #[test]
    fn all_rejected_cross_task_splits_into_grid() {
        let mut s = Scheduler::new(vec![task(0, 1, 2)], Policy::Fifo);
        let mem = 20u64 * 10 * 40;
        s.set_task_meta(
            [(0u32, mem)].into_iter().collect(),
            [(0u32, (10u32, 40u32))].into_iter().collect(),
        );
        s.add_service(ServiceId(0));
        s.set_service_budget(ServiceId(0), Some(20 * 10 * 10));
        let t = s.next_task(ServiceId(0)).unwrap();
        assert!(s.reject_task(ServiceId(0), t.id));
        // 10×40 cells at a 100-cell budget → a 1×4 grid of 10×10
        // rectangles
        assert_eq!(s.remaining(), 4);
        let mut covered = 0u64;
        for _ in 0..4 {
            let c = s.next_task(ServiceId(0)).unwrap();
            let span = s.span_of(c.id).unwrap();
            assert_eq!(span.left, (0, 10));
            assert_eq!(span.right_len(), 10);
            assert!(s.mem_of(c.id) <= 20 * 10 * 10);
            covered +=
                span.left_len() as u64 * span.right_len() as u64;
            assert!(s.try_report_complete(ServiceId(0), c.id, vec![]));
        }
        assert_eq!(covered, 400, "grid tiles the full rectangle");
        assert!(s.is_done());
        assert_eq!(s.completed(), 1);
    }

    /// A sub-task the (now smaller) cluster rejects again splits
    /// recursively, and the root still completes exactly once.
    #[test]
    fn split_subtask_rejected_again_splits_recursively() {
        let mut s = Scheduler::new(vec![task(0, 3, 3)], Policy::Fifo);
        let mem = 20u64 * 40 * 40;
        s.set_task_meta(
            [(0u32, mem)].into_iter().collect(),
            [(0u32, (40u32, 40u32))].into_iter().collect(),
        );
        s.add_service(ServiceId(0));
        s.set_service_budget(ServiceId(0), Some(20 * 20 * 20));
        let t = s.next_task(ServiceId(0)).unwrap();
        assert!(s.reject_task(ServiceId(0), t.id));
        assert_eq!(s.remaining(), 3, "2 chunks of 20");
        // the cluster's budget shrinks mid-run
        s.set_service_budget(ServiceId(0), Some(20 * 10 * 10));
        let c = s.next_task(ServiceId(0)).unwrap();
        assert!(s.reject_task(ServiceId(0), c.id));
        assert_eq!(s.runtime_splits(), 2, "nested split");
        // drain everything; the root completes exactly once
        while let Some(t) = s.next_task(ServiceId(0)) {
            assert!(s.try_report_complete(ServiceId(0), t.id, vec![]));
        }
        assert!(s.is_done());
        assert_eq!(s.completed(), 1);
        assert_eq!(s.total(), 1);
    }

    /// A task whose single pair already exceeds the smallest budget
    /// cannot be reshaped: the typed misfit carries the numbers an
    /// operator needs.
    #[test]
    fn unsplittable_task_raises_typed_misfit() {
        let mut s = Scheduler::new(vec![task(0, 0, 0)], Policy::Fifo);
        s.set_task_meta(
            [(0u32, 20u64 * 4)].into_iter().collect(),
            [(0u32, (2u32, 2u32))].into_iter().collect(),
        );
        for id in 0..2 {
            s.add_service(ServiceId(id));
            s.set_service_budget(ServiceId(id), Some(10)); // < one pair
        }
        for id in 0..2 {
            let t = s.next_task(ServiceId(id)).unwrap();
            assert!(s.reject_task(ServiceId(id), t.id));
        }
        let misfit = s.misfit().expect("typed misfit raised").clone();
        assert_eq!(misfit.task_id, 0);
        assert_eq!(misfit.mem_bytes, 80);
        assert_eq!(misfit.smallest_budget, 10);
        assert!(misfit.to_string().contains("does not fit"));
        assert_eq!(s.runtime_splits(), 0);
        // the task is still open: a roomier late joiner can rescue it
        s.add_service(ServiceId(9));
        let t = s.next_task(ServiceId(9)).unwrap();
        assert!(s.try_report_complete(ServiceId(9), t.id, vec![]));
        assert!(s.is_done());
    }

    /// Reshaping waits for the engine's expected cluster size: the
    /// first (small) node rejecting everything must not split tasks
    /// while its roomier peers are still connecting.
    #[test]
    fn split_deferred_until_expected_cluster_assembles() {
        let mut s = Scheduler::new(vec![task(0, 0, 0)], Policy::Fifo);
        s.set_task_meta(
            [(0u32, 20u64 * 10 * 10)].into_iter().collect(),
            [(0u32, (10u32, 10u32))].into_iter().collect(),
        );
        s.set_min_split_services(2);
        s.add_service(ServiceId(0));
        s.set_service_budget(ServiceId(0), Some(100));
        let t = s.next_task(ServiceId(0)).unwrap();
        assert!(s.reject_task(ServiceId(0), t.id));
        // only 1 of the 2 expected services has joined: no verdict yet
        assert_eq!(s.runtime_splits(), 0);
        assert!(s.misfit().is_none());
        assert_eq!(s.remaining(), 1);
        // the second (equally small) node joins and rejects too — now
        // the cluster is assembled and the split happens
        s.add_service(ServiceId(1));
        s.set_service_budget(ServiceId(1), Some(100));
        let t = s.next_task(ServiceId(1)).unwrap();
        assert!(s.reject_task(ServiceId(1), t.id));
        assert_eq!(s.runtime_splits(), 1);
        while let Some(t) = s.next_task(ServiceId(0)) {
            assert!(s.mem_of(t.id) <= 100, "sub-task fits the budget");
            assert!(s.try_report_complete(ServiceId(0), t.id, vec![]));
        }
        assert!(s.is_done());
        assert_eq!(s.completed(), 1);
    }

    /// Losing the last service that could still take an oversize task
    /// reshapes it immediately — the failure path must not create a
    /// new stall class.
    #[test]
    fn service_failure_reshapes_tasks_left_without_takers() {
        let mut s = Scheduler::new(vec![task(0, 0, 0)], Policy::Fifo);
        s.set_task_meta(
            [(0u32, 20u64 * 12 * 12)].into_iter().collect(),
            [(0u32, (12u32, 12u32))].into_iter().collect(),
        );
        for id in 0..3 {
            s.add_service(ServiceId(id));
        }
        s.set_service_budget(ServiceId(0), Some(20 * 6 * 6));
        s.set_service_budget(ServiceId(1), Some(20 * 6 * 6));
        // service 2 reports no budget (unlimited) — it keeps the task
        // placeable while services 0 and 1 reject it
        for id in 0..2 {
            let t = s.next_task(ServiceId(id)).unwrap();
            assert!(s.reject_task(ServiceId(id), t.id));
        }
        assert_eq!(s.runtime_splits(), 0, "still one taker left");
        // the unlimited service dies before ever pulling: the live
        // set shrinks and the stranded task is reshaped, not stalled
        assert_eq!(s.fail_service(ServiceId(2)), 0);
        assert_eq!(s.runtime_splits(), 1);
        assert!(s.misfit().is_none());
        while let Some(t) = s.next_task(ServiceId(0)) {
            assert!(s.try_report_complete(ServiceId(0), t.id, vec![]));
        }
        assert!(s.is_done());
        assert_eq!(s.completed(), 1);
    }

    #[test]
    fn stale_completion_after_failure_is_rejected_not_fatal() {
        let mut s = Scheduler::new(
            vec![task(0, 0, 0), task(1, 1, 1)],
            Policy::Fifo,
        );
        let t = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(s.fail_service(ServiceId(0)), 1);
        // the "dead" service reports anyway — dropped, not double-counted
        assert!(!s.try_report_complete(ServiceId(0), t.id, vec![]));
        assert_eq!(s.completed(), 0);
        // the re-queued task completes at another service exactly once
        let re = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(re.id, t.id);
        assert!(s.try_report_complete(ServiceId(1), re.id, vec![]));
        // and a duplicate report of the same completion is rejected too
        assert!(!s.try_report_complete(ServiceId(1), re.id, vec![]));
        let t1 = s.next_task(ServiceId(1)).unwrap();
        assert!(s.try_report_complete(ServiceId(1), t1.id, vec![]));
        assert!(s.is_done());
        assert_eq!(s.completed(), 2);
    }

    /// The tracer hooks: a run with rejection-driven runtime
    /// splitting, a node failure with requeueing, and straggler
    /// duplicates leaves a trace the exactly-once verifier certifies.
    #[test]
    fn tracer_records_verifiable_lifecycle() {
        let mut s = Scheduler::new(
            vec![task(0, 7, 7), task(1, 1, 2)],
            Policy::Fifo,
        );
        // §3.1 metadata so task 0 (30×30 intra) can be runtime-split
        s.set_task_meta(
            [(0u32, 20u64 * 30 * 30)].into_iter().collect(),
            [(0u32, (30u32, 30u32))].into_iter().collect(),
        );
        let tracer = Tracer::new(1 << 12);
        s.set_tracer(tracer.clone());
        for id in 0..2 {
            s.add_service(ServiceId(id));
            s.set_service_budget(ServiceId(id), Some(20 * 15 * 15));
        }
        // both services reject task 0 → split into 3 sub-tasks
        let t = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t.id, 0);
        assert!(s.reject_task(ServiceId(0), t.id));
        let held = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(held.id, 1);
        let t = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(t.id, 0);
        assert!(s.reject_task(ServiceId(1), t.id));
        assert_eq!(s.runtime_splits(), 1);
        // service 1 completes its plan task, pulls a sub-task, dies
        assert!(s.try_report_complete(ServiceId(1), held.id, vec![]));
        let lost = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(s.fail_service(ServiceId(1)), 1);
        // its straggler duplicate is dropped — and not traced
        assert!(!s.try_report_complete(ServiceId(1), lost.id, vec![]));
        // service 0 drains the sub-tasks; the root completes once
        while let Some(t) = s.next_task(ServiceId(0)) {
            assert!(s.try_report_complete(ServiceId(0), t.id, vec![]));
        }
        assert!(s.is_done());
        let summary = tracer.verify_plan(&[0, 1]).expect("trace verifies");
        assert_eq!(summary.plan_tasks, 2);
        assert_eq!(summary.subtasks, 3, "2 triangles + 1 rectangle");
        assert_eq!(summary.splits, 1);
        assert_eq!(summary.requeues, 1);
        // assignments: t0×2 (rejected twice), t1, sub-task×1 (lost),
        // sub-tasks×3 (drained) = 7
        assert_eq!(summary.assignments, 7);
        assert_eq!(tracer.dropped(), 0);
        let events = tracer.events();
        let completions: Vec<u32> = events
            .iter()
            .filter(|e| e.kind == TraceEventKind::Completed)
            .map(|e| e.task)
            .collect();
        assert_eq!(completions.len(), 2);
        assert!(completions.contains(&0) && completions.contains(&1));
    }

    // ------------------------------------------------- tenants (v7)

    /// Build `k` tenants with `n` tasks each on a fresh scheduler
    /// (empty seed workflow), ids allocated via `reserve_task_ids`.
    fn tenant_sched(
        k: u32,
        n: u32,
        quota: Option<usize>,
        policy: Policy,
    ) -> Scheduler {
        let mut s = Scheduler::new(vec![], policy);
        for tenant in 1..=k {
            let base = s.reserve_task_ids(n);
            let tasks: Vec<MatchTask> = (0..n)
                .map(|i| task(base + i, base + i, base + i))
                .collect();
            s.add_tenant_tasks(
                tenant,
                tasks,
                HashMap::new(),
                HashMap::new(),
                quota,
            );
        }
        s
    }

    /// Property (v7 fairness invariant): for any interleaving of task
    /// pulls from any number of services, as long as every tenant
    /// still has open tasks the per-tenant assignment counts never
    /// diverge by more than one — round-robin tenant selection cannot
    /// let a heavy plan starve a light one.  The interleaving is a
    /// deterministic schedule driven by a [`ManualClock`]: each pull
    /// event gets a random arrival offset, events fire in clock
    /// order, and some completions are interleaved so the open/
    /// in-flight mix varies too.
    #[test]
    fn prop_tenant_fairness_round_robin() {
        use crate::obs::{Clock, ManualClock};
        forall("tenant-fairness", 60, |rng| {
            let k = 2 + rng.gen_range(3) as u32; // 2..=4 tenants
            let n = 4 + rng.gen_range(12) as u32; // tasks per tenant
            let n_services = 1 + rng.gen_range(4);
            let policy = if rng.gen_bool(0.5) {
                Policy::Affinity
            } else {
                Policy::Fifo
            };
            let mut s = tenant_sched(k, n, None, policy);
            for svc in 0..n_services {
                s.add_service(ServiceId(svc));
            }
            // deterministic arrival schedule: the ManualClock advances
            // by a random offset before every pull event
            let clock = ManualClock::new(0);
            let mut assigned: HashMap<u32, usize> = HashMap::new();
            let mut in_flight: Vec<(usize, u32)> = Vec::new();
            loop {
                clock.advance(1 + rng.gen_range(1_000) as u64);
                let _arrival = clock.now_ns();
                let svc = rng.gen_range(n_services);
                if !in_flight.is_empty() && rng.gen_bool(0.3) {
                    // interleave a completion of a random in-flight task
                    let i = rng.gen_range(in_flight.len());
                    let (owner, tid) = in_flight.swap_remove(i);
                    assert!(s.try_report_complete(
                        ServiceId(owner),
                        tid,
                        vec![]
                    ));
                    continue;
                }
                let Some(t) = s.next_task(ServiceId(svc)) else {
                    break; // open list drained
                };
                let tenant = s.tenant_of_task(t.id);
                *assigned.entry(tenant).or_insert(0) += 1;
                in_flight.push((svc, t.id));
                // invariant: among tenants that still have open tasks
                // (assigned < n), counts stay within one of each other
                let backlogged: Vec<usize> = (1..=k)
                    .map(|t| assigned.get(&t).copied().unwrap_or(0))
                    .filter(|&a| a < n as usize)
                    .collect();
                if backlogged.len() >= 2 {
                    let hi = *backlogged.iter().max().unwrap();
                    let lo = *backlogged.iter().min().unwrap();
                    assert!(
                        hi - lo <= 1,
                        "fairness violated: backlogged tenant counts \
                         {backlogged:?} diverge by more than one"
                    );
                }
            }
            // every tenant got everything in the end
            for tenant in 1..=k {
                assert_eq!(assigned[&tenant], n as usize);
            }
            for (owner, tid) in in_flight {
                assert!(s.try_report_complete(ServiceId(owner), tid, vec![]));
            }
            assert!(s.is_done());
            for tenant in 1..=k {
                assert_eq!(s.tenant_progress(tenant), (n as usize, n as usize));
            }
        });
    }

    #[test]
    fn tenant_quota_caps_in_flight() {
        let mut s = tenant_sched(2, 5, Some(1), Policy::Fifo);
        s.add_service(ServiceId(0));
        let a = s.next_task(ServiceId(0)).expect("tenant 1 under quota");
        let b = s.next_task(ServiceId(0)).expect("tenant 2 under quota");
        assert_ne!(s.tenant_of_task(a.id), s.tenant_of_task(b.id));
        // both tenants at their quota: nothing assignable despite a
        // non-empty open list
        assert!(s.next_task(ServiceId(0)).is_none());
        assert_eq!(s.queue_depth(), 8);
        // completing frees the quota slot
        assert!(s.try_report_complete(ServiceId(0), a.id, vec![]));
        let c = s.next_task(ServiceId(0)).expect("slot freed");
        assert_eq!(s.tenant_of_task(c.id), s.tenant_of_task(a.id));
    }

    #[test]
    fn drain_tenant_drops_open_and_inflight() {
        let mut s = tenant_sched(2, 3, None, Policy::Fifo);
        s.add_service(ServiceId(0));
        let a = s.next_task(ServiceId(0)).unwrap(); // tenant 1
        assert_eq!(s.tenant_of_task(a.id), 1);
        assert_eq!(s.total(), 6);
        // drain tenant 1: its in-flight task + 2 open tasks vanish
        assert_eq!(s.drain_tenant(1), 3);
        assert_eq!(s.total(), 3);
        // the straggler completion of the drained task is stale
        assert!(!s.try_report_complete(ServiceId(0), a.id, vec![]));
        // tenant 2 is untouched and completes the workflow
        while let Some(t) = s.next_task(ServiceId(0)) {
            assert_eq!(s.tenant_of_task(t.id), 2);
            assert!(s.try_report_complete(ServiceId(0), t.id, vec![]));
        }
        assert!(s.is_done());
        assert_eq!(s.tenant_progress(2), (3, 3));
    }

    #[test]
    fn tenant_misfit_isolates_failure() {
        // seed workflow: one task; tenant 1: one unsplittable task
        // (a footprint but no split metadata)
        let mut s = Scheduler::new(vec![task(0, 0, 0)], Policy::Fifo);
        s.add_service(ServiceId(0));
        s.set_service_budget(ServiceId(0), Some(100));
        let base = s.reserve_task_ids(1);
        let mem: HashMap<u32, u64> = [(base, 1 << 30)].into();
        s.add_tenant_tasks(
            1,
            vec![task(base, 7, 8)],
            mem,
            HashMap::new(),
            None,
        );
        // round-robin offers tenant 1 first (cursor starts at 0)
        let t = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(s.tenant_of_task(t.id), 1);
        // the only live service rejects it: unplaceable + unsplittable
        assert!(s.reject_task(ServiceId(0), t.id));
        let mis = s.tenant_misfit(1).expect("tenant misfit recorded");
        assert_eq!(mis.task_id, t.id);
        assert_eq!(mis.smallest_budget, 100);
        // ...but only tenant 1 failed: no cluster-wide misfit, and the
        // tenant's work is drained so the workflow still converges
        assert!(s.misfit().is_none());
        let seed = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(seed.id, 0);
        assert!(s.try_report_complete(ServiceId(0), seed.id, vec![]));
        assert!(s.is_done());
    }

    #[test]
    fn split_children_inherit_tenant() {
        let mut s = Scheduler::new(vec![], Policy::Fifo);
        s.add_service(ServiceId(0));
        s.set_service_budget(ServiceId(0), Some(30));
        let base = s.reserve_task_ids(1);
        let mem: HashMap<u32, u64> = [(base, 100)].into();
        let sizes: HashMap<u32, (u32, u32)> = [(base, (4, 4))].into();
        s.add_tenant_tasks(1, vec![task(base, 7, 8)], mem, sizes, None);
        let t = s.next_task(ServiceId(0)).unwrap();
        assert!(s.reject_task(ServiceId(0), t.id));
        assert_eq!(s.runtime_splits(), 1);
        assert!(s.tenant_misfit(1).is_none());
        // every sub-task belongs to tenant 1; completing them all
        // completes the root exactly once
        let mut n_children = 0;
        while let Some(c) = s.next_task(ServiceId(0)) {
            assert_eq!(s.tenant_of_task(c.id), 1);
            assert!(s.span_of(c.id).is_some());
            assert!(s.try_report_complete(ServiceId(0), c.id, vec![]));
            n_children += 1;
        }
        assert!(n_children > 1, "the task was split");
        assert_eq!(s.tenant_progress(1), (1, 1));
        assert!(s.is_done());
    }
}
