//! The workflow service's task list and affinity-based scheduling
//! (paper §4).
//!
//! Pull-based: whenever a match service reports a completed task (with
//! its piggybacked cache status), the workflow service assigns it a new
//! one — preferably a task whose needed partitions are already cached at
//! that service.  Pull scheduling gives dynamic load balancing and copes
//! with heterogeneous nodes for free; the affinity preference adds cache
//! locality.  Failure handling (paper §4): when a match service stops
//! responding, its in-flight tasks are put back on the open list.
//!
//! Failure handling carries a **generation check**: failing a service
//! bumps its generation and marks it dead, so a "resurrected" service
//! — one declared dead that reports anyway — can neither pull new
//! tasks nor complete old ones ([`Scheduler::next_task`] returns
//! `None`, [`Scheduler::try_report_complete`] drops the report).
//! Without it, a zombie could be handed the re-queued copy of its own
//! task and its straggler completion would then satisfy the new
//! assignment — a double-completion.  Revival is explicit: only
//! [`Scheduler::add_service`] (a real re-join; the wire layer always
//! grants a fresh [`ServiceId`]) clears the dead mark.
//!
//! For the v3 batched wire protocol, [`Scheduler::next_tasks_for`]
//! assigns up to `k` tasks in one call, re-ranking the open list
//! between picks so affinity and replica-coverage ordering hold
//! *within* the batch, not just at its head.
//!
//! The §3.1 **memory model** reaches the scheduler through
//! [`Scheduler::reject_task`] (wire `TaskRejected`, protocol v4): a
//! node that cannot fit an assigned task's memory footprint hands it
//! back, the task is re-queued *marked oversize for that service*, and
//! [`Scheduler::next_task`] never offers it to that service again —
//! other nodes (with larger budgets) still receive it, so an oversize
//! task is re-routed instead of lost or endlessly ping-ponged.
//!
//! With a **replicated data plane** the scheduler additionally tracks
//! how many data replicas hold each partition
//! ([`Scheduler::add_replica_coverage`], fed by `ReplicaAnnounce`).
//! Among tasks with equal cache affinity, assignment prefers the task
//! whose partitions are the most widely replicated — those fetches can
//! be served by a nearby, less-loaded replica (the paper's §5 caching +
//! affinity strategy, extended across the network).

use crate::partition::{MatchTask, PartitionId};
use std::collections::{HashMap, HashSet, VecDeque};

/// Identifier of a match service (one per node).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct ServiceId(pub usize);

/// Scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Plain FIFO over the central task list.
    Fifo,
    /// Prefer tasks whose partitions are cached at the requesting
    /// service (the paper's affinity-based scheduling).
    Affinity,
}

/// Central task list + approximate cache status.
#[derive(Debug)]
pub struct Scheduler {
    open: VecDeque<MatchTask>,
    /// task id → (owner, owner's generation at assignment, task).
    in_flight: HashMap<u32, (ServiceId, u32, MatchTask)>,
    cache_status: HashMap<ServiceId, HashSet<PartitionId>>,
    /// Membership epoch per service: bumped by [`Scheduler::fail_service`],
    /// so completions from before a failure can never satisfy an
    /// assignment made after it.
    generation: HashMap<ServiceId, u32>,
    /// Services declared dead and not (re-)added since.
    dead: HashSet<ServiceId>,
    /// task id → services that rejected it as oversize (§3.1 memory
    /// model): the task is never re-offered to those services.
    oversize: HashMap<u32, HashSet<ServiceId>>,
    /// partition → number of data replicas announced as holding it.
    replica_coverage: HashMap<PartitionId, u32>,
    policy: Policy,
    /// Tasks assigned with at least one affinity (cached-partition) hit.
    pub affinity_assignments: u64,
    completed: usize,
    total: usize,
}

impl Scheduler {
    /// Seed the central task list under the given policy.
    pub fn new(tasks: Vec<MatchTask>, policy: Policy) -> Scheduler {
        let total = tasks.len();
        Scheduler {
            open: tasks.into(),
            in_flight: HashMap::new(),
            cache_status: HashMap::new(),
            generation: HashMap::new(),
            dead: HashSet::new(),
            oversize: HashMap::new(),
            replica_coverage: HashMap::new(),
            policy,
            affinity_assignments: 0,
            completed: 0,
            total,
        }
    }

    /// Tasks not yet completed (open + in flight).
    pub fn remaining(&self) -> usize {
        self.open.len() + self.in_flight.len()
    }

    /// Tasks completed exactly once.
    pub fn completed(&self) -> usize {
        self.completed
    }

    /// Tasks the workflow started with.
    pub fn total(&self) -> usize {
        self.total
    }

    /// `true` once every task has completed.
    pub fn is_done(&self) -> bool {
        self.completed == self.total
    }

    /// Assign the next task to `service`, or `None` if the open list is
    /// empty (in-flight tasks may still complete — or fail and reopen)
    /// or the service has been declared dead and not re-added.
    ///
    /// Under [`Policy::Affinity`] the score of a task is the pair
    /// `(cached partitions at the service, replica coverage of its
    /// partitions)`, compared lexicographically: cache locality first,
    /// then — among equally-cached tasks — the one whose partitions the
    /// most data replicas hold, so its fetches can be spread across the
    /// replicated data plane.  Ties go to the oldest task (FIFO).
    pub fn next_task(&mut self, service: ServiceId) -> Option<MatchTask> {
        if self.open.is_empty() || self.dead.contains(&service) {
            return None;
        }
        // tasks this service rejected as oversize are invisible to it
        // (`rejected_by` is one lookup in a normally-empty map, so the
        // FIFO pick stays effectively O(1) and the affinity scan stays
        // one allocation-free pass)
        let idx = match self.policy {
            Policy::Fifo => self
                .open
                .iter()
                .position(|t| !self.rejected_by(t.id, service))?,
            Policy::Affinity => {
                let cached = self.cache_status.get(&service);
                let coverage = &self.replica_coverage;
                let score = |t: &MatchTask| -> (usize, u32) {
                    let hits = match cached {
                        None => 0,
                        Some(set) => t
                            .needed_partitions()
                            .iter()
                            .filter(|p| set.contains(p))
                            .count(),
                    };
                    let cov = t
                        .needed_partitions()
                        .iter()
                        .map(|p| coverage.get(p).copied().unwrap_or(0))
                        .sum();
                    (hits, cov)
                };
                // best score wins; ties go to the oldest task (FIFO)
                let mut best: Option<(usize, (usize, u32))> = None;
                for (i, t) in self.open.iter().enumerate() {
                    if self.rejected_by(t.id, service) {
                        continue;
                    }
                    let s = score(t);
                    let better = match &best {
                        None => true,
                        Some((_, best_score)) => s > *best_score,
                    };
                    if better {
                        best = Some((i, s));
                        if s.0 == 2 && coverage.is_empty() {
                            break; // cannot do better than both cached
                        }
                    }
                }
                let (idx, best_score) = best?;
                if best_score.0 > 0 {
                    self.affinity_assignments += 1;
                }
                idx
            }
        };
        let task = self.open.remove(idx).expect("index valid");
        let epoch = self.generation.get(&service).copied().unwrap_or(0);
        self.in_flight.insert(task.id, (service, epoch, task));
        Some(task)
    }

    /// `true` when `service` has rejected `task` as oversize.
    fn rejected_by(&self, task: u32, service: ServiceId) -> bool {
        self.oversize
            .get(&task)
            .is_some_and(|s| s.contains(&service))
    }

    /// A match service reports that an assigned task's §3.1 memory
    /// footprint exceeds its budget (wire `TaskRejected`, v4): put the
    /// task back on the open list *marked oversize for that service*,
    /// so it is re-offered only to other services.  Subject to the
    /// same freshness rules as [`Self::try_report_complete`] — a
    /// zombie's rejection is dropped (returns `false`).
    ///
    /// A task every service has rejected can never complete; the run's
    /// timeout surfaces that as a failure, which is the §3.1 contract
    /// ("this plan does not fit this cluster") instead of an OOM kill.
    pub fn reject_task(&mut self, service: ServiceId, task_id: u32) -> bool {
        if self.dead.contains(&service) {
            return false;
        }
        let epoch = self.generation.get(&service).copied().unwrap_or(0);
        let fresh = matches!(
            self.in_flight.get(&task_id),
            Some((s, e, _)) if *s == service && *e == epoch
        );
        if fresh {
            let (_, _, task) = self.in_flight.remove(&task_id).unwrap();
            self.oversize.entry(task_id).or_default().insert(service);
            // to the back: every other service sees it soon enough,
            // and the rejecting service's next pull is not dominated
            // by re-ranking the same task it just refused
            self.open.push_back(task);
        }
        fresh
    }

    /// Tasks at least one service has rejected as oversize.
    pub fn oversize_tasks(&self) -> usize {
        self.oversize.len()
    }

    /// Assign up to `max` tasks to `service` in one call (the v3
    /// batched pull).  Each pick re-ranks the remaining open list, so
    /// the affinity / replica-coverage preference of
    /// [`Scheduler::next_task`] orders tasks *within* the batch too.
    /// Returns fewer than `max` (possibly none) when the open list
    /// runs dry or the service is dead.
    pub fn next_tasks_for(
        &mut self,
        service: ServiceId,
        max: usize,
    ) -> Vec<MatchTask> {
        let mut batch = Vec::with_capacity(max.min(self.open.len()));
        for _ in 0..max {
            match self.next_task(service) {
                Some(task) => batch.push(task),
                None => break,
            }
        }
        batch
    }

    /// A data replica announced that it holds `parts`: bump each
    /// partition's replica count.  Called once per announced replica
    /// (the workflow service deduplicates re-announcements).
    pub fn add_replica_coverage(&mut self, parts: &[PartitionId]) {
        for p in parts {
            *self.replica_coverage.entry(*p).or_insert(0) += 1;
        }
    }

    /// How many data replicas hold `p`, as announced so far.
    pub fn replica_coverage(&self, p: PartitionId) -> u32 {
        self.replica_coverage.get(&p).copied().unwrap_or(0)
    }

    /// A match service reports a completed task together with its current
    /// cache content (piggybacked status, paper §4).
    pub fn report_complete(
        &mut self,
        service: ServiceId,
        task_id: u32,
        cached: Vec<PartitionId>,
    ) {
        assert!(
            self.try_report_complete(service, task_id, cached),
            "completion for task {task_id} not in flight at {service:?}"
        );
    }

    /// Like [`Self::report_complete`], but tolerates reports that no
    /// longer match the in-flight table: a service that was presumed dead
    /// (missed heartbeats → [`Self::fail_service`]) may still deliver a
    /// completion for a task that has since been re-queued or re-assigned.
    /// The distributed runtime must not crash on such stragglers — the
    /// stale report is dropped and `false` returned.
    ///
    /// A report is **fresh** only when all three hold: the service has
    /// not been declared dead, the task is in flight at that service,
    /// and the assignment was made in the service's *current*
    /// generation.  The generation check is what stops the
    /// double-completion: without it, a zombie's straggler could
    /// satisfy a post-failure re-assignment of the same task.  The
    /// cache status is recorded only for live services.
    pub fn try_report_complete(
        &mut self,
        service: ServiceId,
        task_id: u32,
        cached: Vec<PartitionId>,
    ) -> bool {
        if self.dead.contains(&service) {
            return false;
        }
        let fresh = self.try_complete_batched(service, task_id);
        self.cache_status
            .insert(service, cached.into_iter().collect());
        fresh
    }

    /// Like [`Self::try_report_complete`] but leaves the service's
    /// recorded cache status untouched: the v3 batch path folds many
    /// completions with this and then records the batch's piggybacked
    /// status once via [`Self::record_cache_status`], instead of
    /// rebuilding the status set per task.
    pub fn try_complete_batched(
        &mut self,
        service: ServiceId,
        task_id: u32,
    ) -> bool {
        if self.dead.contains(&service) {
            return false;
        }
        let epoch = self.generation.get(&service).copied().unwrap_or(0);
        let fresh = matches!(
            self.in_flight.get(&task_id),
            Some((s, e, _)) if *s == service && *e == epoch
        );
        if fresh {
            self.in_flight.remove(&task_id);
            self.completed += 1;
        }
        fresh
    }

    /// Record a service's piggybacked cache status without reporting a
    /// completion.  The v3 batch path sends the status **once per
    /// batch**, so the workflow service folds the batch's completions
    /// with [`Self::try_report_complete`] (empty status) and records
    /// the real status here, instead of rebuilding the status set per
    /// task.  Dead services are ignored.
    pub fn record_cache_status(
        &mut self,
        service: ServiceId,
        cached: Vec<PartitionId>,
    ) {
        if self.dead.contains(&service) {
            return;
        }
        self.cache_status
            .insert(service, cached.into_iter().collect());
    }

    /// A match service was added (paper §4: services can be added on
    /// demand — pull scheduling needs no state, this just primes the
    /// cache-status entry).  Also the only way a previously-failed
    /// [`ServiceId`] becomes assignable again — an explicit re-join,
    /// starting a fresh generation.
    pub fn add_service(&mut self, service: ServiceId) {
        self.dead.remove(&service);
        self.generation.entry(service).or_insert(0);
        self.cache_status.entry(service).or_default();
    }

    /// `true` when `service` was failed and has not re-joined since.
    pub fn is_dead(&self, service: ServiceId) -> bool {
        self.dead.contains(&service)
    }

    /// A match service failed or was removed: requeue its in-flight
    /// tasks (at the front — they are oldest), drop its cache status,
    /// bump its generation and mark it dead (see the module docs on
    /// the generation check).  Returns the number of requeued tasks.
    pub fn fail_service(&mut self, service: ServiceId) -> usize {
        let failed: Vec<u32> = self
            .in_flight
            .iter()
            .filter(|(_, (s, _, _))| *s == service)
            .map(|(id, _)| *id)
            .collect();
        for id in &failed {
            let (_, _, task) = self.in_flight.remove(id).unwrap();
            self.open.push_front(task);
        }
        self.cache_status.remove(&service);
        *self.generation.entry(service).or_insert(0) += 1;
        self.dead.insert(service);
        failed.len()
    }

    /// Known cache status (for tests / introspection).
    pub fn cached_at(&self, service: ServiceId) -> Option<&HashSet<PartitionId>> {
        self.cache_status.get(&service)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn task(id: u32, l: u32, r: u32) -> MatchTask {
        MatchTask {
            id,
            left: PartitionId(l),
            right: PartitionId(r),
        }
    }

    #[test]
    fn fifo_order() {
        let mut s =
            Scheduler::new(vec![task(0, 0, 0), task(1, 1, 1)], Policy::Fifo);
        assert_eq!(s.next_task(ServiceId(0)).unwrap().id, 0);
        assert_eq!(s.next_task(ServiceId(1)).unwrap().id, 1);
        assert!(s.next_task(ServiceId(0)).is_none());
        assert_eq!(s.remaining(), 2);
        s.report_complete(ServiceId(0), 0, vec![PartitionId(0)]);
        s.report_complete(ServiceId(1), 1, vec![PartitionId(1)]);
        assert!(s.is_done());
    }

    #[test]
    fn affinity_prefers_cached_partitions() {
        let tasks = vec![task(0, 0, 1), task(1, 2, 3), task(2, 2, 2)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        // service 0 reports partitions 2,3 cached after its first task
        let t0 = s.next_task(ServiceId(0)).unwrap(); // FIFO first: task 0
        assert_eq!(t0.id, 0);
        s.report_complete(
            ServiceId(0),
            0,
            vec![PartitionId(2), PartitionId(3)],
        );
        // next assignment should pick task 1 (both partitions cached)
        let t1 = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t1.id, 1);
        assert_eq!(s.affinity_assignments, 1);
    }

    #[test]
    fn affinity_falls_back_to_fifo_without_status() {
        let mut s = Scheduler::new(
            vec![task(0, 0, 0), task(1, 1, 1)],
            Policy::Affinity,
        );
        assert_eq!(s.next_task(ServiceId(5)).unwrap().id, 0);
        assert_eq!(s.affinity_assignments, 0);
    }

    #[test]
    fn failure_requeues_in_flight() {
        let mut s = Scheduler::new(
            vec![task(0, 0, 0), task(1, 1, 1), task(2, 2, 2)],
            Policy::Fifo,
        );
        let a = s.next_task(ServiceId(0)).unwrap();
        let _b = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(s.fail_service(ServiceId(0)), 1);
        // the failed task is back at the front
        let re = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(re.id, a.id);
        // completing everything still reaches done
        s.report_complete(ServiceId(1), 1, vec![]);
        s.report_complete(ServiceId(1), 0, vec![]);
        let c = s.next_task(ServiceId(1)).unwrap();
        s.report_complete(ServiceId(1), c.id, vec![]);
        assert!(s.is_done());
    }

    #[test]
    #[should_panic]
    fn wrong_service_completion_panics() {
        let mut s = Scheduler::new(vec![task(0, 0, 0)], Policy::Fifo);
        let _ = s.next_task(ServiceId(0)).unwrap();
        s.report_complete(ServiceId(1), 0, vec![]);
    }

    /// Property: under any interleaving of assignment/completion/failure
    /// (every failed node re-joining under a fresh id, as the wire
    /// layer guarantees), every task is eventually completed exactly
    /// once.
    #[test]
    fn prop_all_tasks_complete_exactly_once() {
        forall("scheduler-complete", 80, |rng| {
            let n_tasks = 1 + rng.gen_range(60);
            let n_services = 1 + rng.gen_range(5);
            let tasks: Vec<MatchTask> = (0..n_tasks as u32)
                .map(|i| task(i, i % 7, (i * 3) % 7))
                .collect();
            let policy = if rng.gen_bool(0.5) {
                Policy::Affinity
            } else {
                Policy::Fifo
            };
            let mut s = Scheduler::new(tasks, policy);
            // slot → the ServiceId currently joined for that node; a
            // failed node re-joins under a fresh id (like the wire
            // layer, which never reuses ids)
            let mut ids: Vec<usize> = (0..n_services).collect();
            let mut next_id = n_services;
            for &id in &ids {
                s.add_service(ServiceId(id));
            }
            let mut holding: Vec<Vec<MatchTask>> =
                vec![Vec::new(); n_services];
            let mut completions: Vec<u32> = Vec::new();
            let mut failures = 0;
            while !s.is_done() {
                let svc = rng.gen_range(n_services);
                match rng.gen_range(10) {
                    // occasionally fail a service (max 3 times per run)
                    0 if failures < 3 && !holding[svc].is_empty() => {
                        let old = ServiceId(ids[svc]);
                        s.fail_service(old);
                        holding[svc].clear();
                        failures += 1;
                        // the dead id is out of the game for good
                        assert!(s.next_task(old).is_none());
                        // re-join under a fresh id
                        ids[svc] = next_id;
                        next_id += 1;
                        s.add_service(ServiceId(ids[svc]));
                    }
                    // complete something it holds
                    1..=5 if !holding[svc].is_empty() => {
                        let t = holding[svc].pop().unwrap();
                        s.report_complete(
                            ServiceId(ids[svc]),
                            t.id,
                            t.needed_partitions(),
                        );
                        completions.push(t.id);
                    }
                    // otherwise pull a new task
                    _ => {
                        if let Some(t) =
                            s.next_task(ServiceId(ids[svc]))
                        {
                            holding[svc].push(t);
                        } else if holding.iter().all(Vec::is_empty) {
                            // nothing open and nothing held anywhere,
                            // but not done? impossible — fail loudly.
                            assert!(
                                s.is_done(),
                                "deadlock: open empty, nothing held"
                            );
                        }
                    }
                }
            }
            completions.sort_unstable();
            completions.dedup();
            assert_eq!(completions.len(), n_tasks, "each task once");
        });
    }

    /// With equal cache affinity (here: none), assignment prefers the
    /// task whose partitions are held by the most data replicas.
    #[test]
    fn replica_coverage_breaks_affinity_ties() {
        let tasks = vec![task(0, 0, 1), task(1, 2, 3)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        // two replicas announced holding partitions 2 and 3; only one
        // holds 0 and 1
        s.add_replica_coverage(&[
            PartitionId(0),
            PartitionId(1),
            PartitionId(2),
            PartitionId(3),
        ]);
        s.add_replica_coverage(&[PartitionId(2), PartitionId(3)]);
        assert_eq!(s.replica_coverage(PartitionId(2)), 2);
        assert_eq!(s.replica_coverage(PartitionId(0)), 1);
        assert_eq!(s.replica_coverage(PartitionId(99)), 0);
        // no cache status → cache score ties at 0 → coverage decides
        let t = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t.id, 1, "widely-replicated task preferred");
        // coverage alone is not an affinity (cache) hit
        assert_eq!(s.affinity_assignments, 0);
    }

    /// Cache affinity still dominates replica coverage: a task cached
    /// at the service wins even when another task is better replicated.
    #[test]
    fn cache_affinity_dominates_replica_coverage() {
        let tasks = vec![task(0, 9, 9), task(1, 5, 6), task(2, 2, 3)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        // no status, no coverage yet → plain FIFO for the first pull
        let t0 = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t0.id, 0);
        s.report_complete(ServiceId(0), 0, vec![PartitionId(5)]);
        // three replicas announce partitions 2 and 3 (task 2's pair)
        for _ in 0..3 {
            s.add_replica_coverage(&[PartitionId(2), PartitionId(3)]);
        }
        // task 1 has one cached partition; task 2 has 3× coverage but
        // nothing cached — cache locality must win
        assert_eq!(s.next_task(ServiceId(0)).unwrap().id, 1);
        assert_eq!(s.affinity_assignments, 1);
    }

    #[test]
    fn add_service_primes_status() {
        let mut s = Scheduler::new(vec![task(0, 0, 0)], Policy::Affinity);
        s.add_service(ServiceId(3));
        assert!(s.cached_at(ServiceId(3)).unwrap().is_empty());
    }

    #[test]
    fn affinity_tie_breaks_to_oldest_task() {
        // tasks 1 and 2 both score one cached partition; the tie must go
        // to the older (lower-index) task, i.e. FIFO within a score class
        let tasks = vec![task(0, 8, 9), task(1, 5, 6), task(2, 5, 7)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        let t0 = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t0.id, 0);
        s.report_complete(ServiceId(0), 0, vec![PartitionId(5)]);
        let t = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t.id, 1, "tie between 1 and 2 must pick the older");
        assert_eq!(s.affinity_assignments, 1);
    }

    #[test]
    fn affinity_double_hit_beats_single_hit() {
        // task 2 has both partitions cached and must win over task 1
        // (one cached) even though task 1 is older
        let tasks = vec![task(0, 9, 9), task(1, 2, 8), task(2, 2, 3)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        let t0 = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(t0.id, 0);
        s.report_complete(
            ServiceId(1),
            0,
            vec![PartitionId(2), PartitionId(3)],
        );
        assert_eq!(s.next_task(ServiceId(1)).unwrap().id, 2);
    }

    #[test]
    fn affinity_zero_scores_fall_back_to_fifo_order() {
        let tasks = vec![task(0, 1, 2), task(1, 3, 4)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        let t0 = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t0.id, 0);
        s.report_complete(ServiceId(0), 0, vec![PartitionId(99)]);
        // nothing cached matches the remaining task: FIFO, no affinity hit
        assert_eq!(s.next_task(ServiceId(0)).unwrap().id, 1);
        assert_eq!(s.affinity_assignments, 0);
    }

    #[test]
    fn fail_service_requeues_all_in_flight_and_drops_status() {
        let tasks =
            vec![task(0, 0, 0), task(1, 1, 1), task(2, 2, 2), task(3, 3, 3)];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        // service 0 holds tasks 0 and 1, service 1 holds task 2
        let a0 = s.next_task(ServiceId(0)).unwrap();
        let a1 = s.next_task(ServiceId(0)).unwrap();
        let b = s.next_task(ServiceId(1)).unwrap();
        s.report_complete(ServiceId(1), b.id, vec![PartitionId(2)]);
        assert_eq!(s.fail_service(ServiceId(0)), 2);
        assert!(s.cached_at(ServiceId(0)).is_none(), "status dropped");
        // the dead service's tasks are at the front of the open list and
        // the workflow still completes through the surviving service
        let ids: Vec<u32> = std::iter::from_fn(|| s.next_task(ServiceId(1)))
            .map(|t| t.id)
            .collect();
        assert_eq!(ids.len(), 3);
        // re-queued tasks go to the front, ahead of the never-assigned
        // task 3 (their mutual order depends on in-flight iteration)
        let front: std::collections::HashSet<u32> =
            ids[..2].iter().copied().collect();
        assert_eq!(
            front,
            [a0.id, a1.id].into_iter().collect(),
            "failed tasks re-queued at the front"
        );
        assert_eq!(ids[2], 3);
        for id in &ids {
            s.report_complete(ServiceId(1), *id, vec![]);
        }
        assert!(s.is_done());
        assert_eq!(s.completed(), 4);
    }

    /// The PR-3 bugfix, reproduced: before the generation check, a
    /// service declared dead could keep pulling (the wire layer's old
    /// `touch` silently resurrected it), be handed the re-queued copy
    /// of its *own* in-flight task, and its straggler report from the
    /// first assignment then completed the second one — the workflow
    /// could finish while the re-execution was still running, and the
    /// "dead" node kept computing against a task the scheduler had
    /// re-opened.  Now the dead id is fenced until an explicit
    /// re-join.
    #[test]
    fn resurrected_service_cannot_pull_or_complete() {
        let mut s = Scheduler::new(
            vec![task(0, 0, 0), task(1, 1, 1)],
            Policy::Fifo,
        );
        s.add_service(ServiceId(0));
        let t = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(s.fail_service(ServiceId(0)), 1);
        assert!(s.is_dead(ServiceId(0)));
        // the zombie pulls again: with the old code this handed task 0
        // back to the dead id — now it gets nothing
        assert!(s.next_task(ServiceId(0)).is_none());
        // and its straggler completion is dropped
        assert!(!s.try_report_complete(ServiceId(0), t.id, vec![]));
        assert_eq!(s.completed(), 0);
        // the re-queued task completes exactly once at a live service
        s.add_service(ServiceId(1));
        let re = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(re.id, t.id);
        assert!(s.try_report_complete(ServiceId(1), re.id, vec![]));
        // an explicit re-join revives the old id in a new generation:
        // it can work again, but nothing from before the failure counts
        s.add_service(ServiceId(0));
        assert!(!s.is_dead(ServiceId(0)));
        assert!(!s.try_report_complete(ServiceId(0), t.id, vec![]));
        let t1 = s.next_task(ServiceId(0)).unwrap();
        assert!(s.try_report_complete(ServiceId(0), t1.id, vec![]));
        assert!(s.is_done());
        assert_eq!(s.completed(), 2);
    }

    /// Batched assignment keeps the affinity ordering *within* a
    /// batch: with partitions 5/6 cached, both tasks touching them
    /// come first, best score first, before the cold task.
    #[test]
    fn next_tasks_for_orders_batch_by_affinity() {
        let tasks = vec![
            task(0, 9, 9),
            task(1, 7, 8),
            task(2, 5, 7),
            task(3, 5, 6),
        ];
        let mut s = Scheduler::new(tasks, Policy::Affinity);
        s.add_service(ServiceId(0));
        let t0 = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t0.id, 0);
        s.report_complete(
            ServiceId(0),
            0,
            vec![PartitionId(5), PartitionId(6)],
        );
        let batch = s.next_tasks_for(ServiceId(0), 3);
        assert_eq!(
            batch.iter().map(|t| t.id).collect::<Vec<_>>(),
            vec![3, 2, 1],
            "both-cached, then one-cached, then cold"
        );
        // a further pull drains nothing: the open list is empty
        assert!(s.next_tasks_for(ServiceId(0), 4).is_empty());
        // dead services get empty batches
        s.fail_service(ServiceId(0));
        assert!(s.next_tasks_for(ServiceId(0), 4).is_empty());
    }

    /// §3.1 memory-model parity: a rejected-oversize task is re-queued
    /// and re-routed to other services, but never re-offered to the
    /// service that rejected it.
    #[test]
    fn oversize_rejection_requeues_and_excludes_the_rejector() {
        let mut s = Scheduler::new(
            vec![task(0, 0, 0), task(1, 1, 1)],
            Policy::Fifo,
        );
        s.add_service(ServiceId(0));
        s.add_service(ServiceId(1));
        let t = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(t.id, 0);
        assert!(s.reject_task(ServiceId(0), t.id), "fresh rejection");
        assert_eq!(s.oversize_tasks(), 1);
        assert_eq!(s.remaining(), 2, "nothing lost");
        // a duplicate rejection of the same task is stale
        assert!(!s.reject_task(ServiceId(0), t.id));
        // the rejector only ever sees the other task again
        let n = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(n.id, 1);
        assert!(s.next_task(ServiceId(0)).is_none(), "task 0 invisible");
        // another service picks the oversize task up
        let re = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(re.id, 0);
        s.report_complete(ServiceId(1), re.id, vec![]);
        s.report_complete(ServiceId(0), n.id, vec![]);
        assert!(s.is_done());
    }

    /// A task rejected by every service stays open (visible in
    /// `remaining`), it is not spun between nodes.
    #[test]
    fn task_rejected_by_all_services_stays_open() {
        let mut s = Scheduler::new(vec![task(0, 0, 0)], Policy::Affinity);
        for id in 0..2 {
            s.add_service(ServiceId(id));
        }
        for id in 0..2 {
            let t = s.next_task(ServiceId(id)).unwrap();
            assert_eq!(t.id, 0);
            assert!(s.reject_task(ServiceId(id), t.id));
        }
        assert!(s.next_task(ServiceId(0)).is_none());
        assert!(s.next_task(ServiceId(1)).is_none());
        assert_eq!(s.remaining(), 1);
        assert!(!s.is_done());
        // a fresh service (bigger budget) can still complete it
        s.add_service(ServiceId(2));
        let t = s.next_task(ServiceId(2)).unwrap();
        assert!(s.try_report_complete(ServiceId(2), t.id, vec![]));
        assert!(s.is_done());
    }

    #[test]
    fn stale_completion_after_failure_is_rejected_not_fatal() {
        let mut s = Scheduler::new(
            vec![task(0, 0, 0), task(1, 1, 1)],
            Policy::Fifo,
        );
        let t = s.next_task(ServiceId(0)).unwrap();
        assert_eq!(s.fail_service(ServiceId(0)), 1);
        // the "dead" service reports anyway — dropped, not double-counted
        assert!(!s.try_report_complete(ServiceId(0), t.id, vec![]));
        assert_eq!(s.completed(), 0);
        // the re-queued task completes at another service exactly once
        let re = s.next_task(ServiceId(1)).unwrap();
        assert_eq!(re.id, t.id);
        assert!(s.try_report_complete(ServiceId(1), re.id, vec![]));
        // and a duplicate report of the same completion is rejected too
        assert!(!s.try_report_complete(ServiceId(1), re.id, vec![]));
        let t1 = s.next_task(ServiceId(1)).unwrap();
        assert!(s.try_report_complete(ServiceId(1), t1.id, vec![]));
        assert!(s.is_done());
        assert_eq!(s.completed(), 2);
    }
}
