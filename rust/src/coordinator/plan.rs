//! The first-class match plan: the inspectable artifact between
//! planning and execution.
//!
//! [`MatchPlan`] captures everything the pre-processing half of the
//! Figure-1 workflow decides — the tuned [`PartitionSet`], the
//! generated [`MatchTask`] list, each task's §3.1 memory footprint, and
//! the provenance (strategy, parameters, dataset fingerprint, computing
//! environment) that produced them.  A plan can be printed (`pem plan`),
//! analyzed for skew ([`MatchPlan::skew`]), serialized to a stable byte
//! format ([`MatchPlan::to_bytes`] / [`MatchPlan::from_bytes`]) and
//! handed to any [`crate::engine::backend::ExecutionBackend`] — the
//! execute half — without re-planning.
//!
//! The serialization is canonical: building the same plan twice from
//! the same dataset, strategy and environment yields byte-identical
//! output (property-tested in `tests/plan_determinism.rs`), so plans
//! can be diffed, cached and shipped.

use crate::cluster::ComputingEnv;
use crate::matching::StrategyKind;
use crate::model::Dataset;
use crate::partition::{
    task_memory_bytes, MatchTask, PartitionId, PartitionKind,
    PartitionSet, PartitionStrategy, PlanContext,
};
use crate::util::{fmt_bytes, fnv1a};
use anyhow::{bail, Result};

/// Magic prefix + format version of the serialized plan.
const PLAN_MAGIC: &[u8; 8] = b"PEMPLAN\x01";

/// Where a plan came from: enough to reproduce it and to refuse to
/// execute it against the wrong dataset.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanProvenance {
    /// Partition strategy name ([`PartitionStrategy::name`]).
    pub strategy: String,
    /// Strategy parameter string ([`PartitionStrategy::params`]).
    pub params: String,
    /// Match strategy (WAM or LRM) the plan was sized for.
    pub match_kind: StrategyKind,
    /// Entities in the planned dataset.
    pub dataset_entities: u64,
    /// FNV-1a fingerprint over the dataset's entity ids *and titles*
    /// ([`dataset_fingerprint`]), so both structural and content
    /// drift between planning and execution is caught.
    pub dataset_fingerprint: u64,
    /// Computing environment: nodes.
    pub nodes: u32,
    /// Computing environment: cores per node.
    pub cores_per_node: u32,
    /// Computing environment: match threads per node.
    pub threads_per_node: u32,
    /// Computing environment: memory per node, bytes.
    pub max_mem: u64,
}

/// Task-skew statistics of a plan (what `pem plan` prints so operators
/// can see load imbalance *before* paying for execution).
#[derive(Clone, Copy, Debug)]
pub struct PlanSkew {
    /// Match tasks in the plan.
    pub n_tasks: usize,
    /// Total pair comparisons across all tasks.
    pub total_pairs: u64,
    /// Pair comparisons of the heaviest task.
    pub max_pairs: u64,
    /// Mean pair comparisons per task.
    pub mean_pairs: f64,
    /// `max_pairs / mean_pairs` — 1.0 is perfectly even; large values
    /// mean one straggler task dominates the makespan.
    pub skew_ratio: f64,
    /// Largest §3.1 task memory footprint, bytes.
    pub max_task_mem: u64,
}

/// A complete, executable match plan (see module docs).
#[derive(Debug)]
pub struct MatchPlan {
    /// Where the plan came from.
    pub provenance: PlanProvenance,
    /// The tuned partitions.
    pub partitions: PartitionSet,
    /// The generated match tasks.
    pub tasks: Vec<MatchTask>,
    /// §3.1 memory footprint (`c_ms · m₁ · m₂`) per task, parallel to
    /// [`MatchPlan::tasks`].
    pub task_mem: Vec<u64>,
}

/// FNV-1a fingerprint over a dataset's entity ids and title values
/// (order-sensitive).  Titles are included so a dataset whose ids
/// survived but whose *content* changed (e.g. a re-exported CSV with
/// corrected titles) no longer matches a stale plan — for a
/// sort-key-sensitive strategy like sorted-neighborhood, executing
/// against drifted content would silently lose coverage.  Callers
/// executing a *deserialized* plan through a backend directly (rather
/// than [`crate::coordinator::PlannedWorkflow::execute`], which
/// checks) should verify [`MatchPlan::matches_dataset`] themselves.
pub fn dataset_fingerprint(dataset: &Dataset) -> u64 {
    let mut bytes =
        Vec::with_capacity(16 + dataset.entities.len() * 24);
    bytes.extend_from_slice(
        &(dataset.entities.len() as u64).to_le_bytes(),
    );
    for e in &dataset.entities {
        bytes.extend_from_slice(&e.id.0.to_le_bytes());
        let title = e.title(&dataset.schema);
        bytes.extend_from_slice(&(title.len() as u32).to_le_bytes());
        bytes.extend_from_slice(title.as_bytes());
    }
    fnv1a(&bytes)
}

impl MatchPlan {
    /// Run the planning half of the workflow: partition the dataset
    /// with `strategy`, generate the tasks, and compute the per-task
    /// memory footprints under the §3.1 model.
    pub fn build(
        dataset: &Dataset,
        strategy: &dyn PartitionStrategy,
        match_kind: StrategyKind,
        ce: &ComputingEnv,
    ) -> Result<MatchPlan> {
        let ctx = PlanContext { ce, match_kind };
        let partitions = strategy.partition(dataset, &ctx)?;
        let tasks = strategy.tasks(&partitions);
        let task_mem: Vec<u64> = tasks
            .iter()
            .map(|t| {
                task_memory_bytes(
                    partitions.get(t.left).len(),
                    partitions.get(t.right).len(),
                    match_kind,
                )
            })
            .collect();
        Ok(MatchPlan {
            provenance: PlanProvenance {
                strategy: strategy.name().to_string(),
                params: strategy.params(),
                match_kind,
                dataset_entities: dataset.entities.len() as u64,
                dataset_fingerprint: dataset_fingerprint(dataset),
                nodes: ce.nodes as u32,
                cores_per_node: ce.cores_per_node as u32,
                threads_per_node: ce.threads_per_node as u32,
                max_mem: ce.max_mem,
            },
            partitions,
            tasks,
            task_mem,
        })
    }

    /// Number of match tasks.
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Number of partitions.
    pub fn n_partitions(&self) -> usize {
        self.partitions.len()
    }

    /// Number of misc partitions (§3.2).
    pub fn n_misc_partitions(&self) -> usize {
        self.partitions.n_misc()
    }

    /// Total pair comparisons across all tasks.
    pub fn total_pairs(&self) -> u64 {
        self.tasks
            .iter()
            .map(|t| t.n_pairs(&self.partitions))
            .sum()
    }

    /// Task-skew statistics.  Guarded against the empty-task-list
    /// case (an empty dataset, or blocking that yields no pairs):
    /// `mean_pairs` and `skew_ratio` are always finite — dividing by
    /// `pairs.len()` or a zero mean would otherwise propagate NaN
    /// into `pem plan` output and the serialized stats.
    pub fn skew(&self) -> PlanSkew {
        let pairs: Vec<u64> = self
            .tasks
            .iter()
            .map(|t| t.n_pairs(&self.partitions))
            .collect();
        let total: u64 = pairs.iter().sum();
        let max = pairs.iter().copied().max().unwrap_or(0);
        let mean = if pairs.is_empty() {
            0.0
        } else {
            total as f64 / pairs.len() as f64
        };
        // a plan with no pairs is perfectly balanced, not 0/0 = NaN
        let skew_ratio = if mean > 0.0 { max as f64 / mean } else { 1.0 };
        debug_assert!(mean.is_finite() && skew_ratio.is_finite());
        PlanSkew {
            n_tasks: pairs.len(),
            total_pairs: total,
            max_pairs: max,
            mean_pairs: mean,
            skew_ratio,
            max_task_mem: self.task_mem.iter().copied().max().unwrap_or(0),
        }
    }

    /// Per-task `(left, right)` partition entity counts — the split
    /// metadata the runtime scheduler needs to reshape a task no
    /// node's §3.1 budget fits (fed to the workflow service alongside
    /// the footprints).
    pub fn task_sizes(
        &self,
    ) -> std::collections::HashMap<u32, (u32, u32)> {
        self.tasks
            .iter()
            .map(|t| {
                (
                    t.id,
                    (
                        self.partitions.get(t.left).len() as u32,
                        self.partitions.get(t.right).len() as u32,
                    ),
                )
            })
            .collect()
    }

    /// The `k` heaviest tasks as `(task, pairs, mem_bytes)`, heaviest
    /// first — the stragglers an operator inspects before executing.
    pub fn top_tasks(&self, k: usize) -> Vec<(MatchTask, u64, u64)> {
        let mut ranked: Vec<(MatchTask, u64, u64)> = self
            .tasks
            .iter()
            .zip(self.task_mem.iter())
            .map(|(t, &m)| (*t, t.n_pairs(&self.partitions), m))
            .collect();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.id.cmp(&b.0.id)));
        ranked.truncate(k);
        ranked
    }

    /// Check the plan was built for `dataset` (same entity-id
    /// fingerprint); executing a plan against a different dataset is
    /// refused by the workflow layer.
    pub fn matches_dataset(&self, dataset: &Dataset) -> bool {
        self.provenance.dataset_entities
            == dataset.entities.len() as u64
            && self.provenance.dataset_fingerprint
                == dataset_fingerprint(dataset)
    }

    /// Multi-line human-readable summary (what `pem plan` prints).
    pub fn summary(&self) -> String {
        let p = &self.provenance;
        let s = self.skew();
        let mut out = String::new();
        out.push_str(&format!(
            "plan: {} ({}) for {} over {} entities (fingerprint \
             {:016x})\n",
            p.strategy,
            p.params,
            p.match_kind.name(),
            p.dataset_entities,
            p.dataset_fingerprint
        ));
        out.push_str(&format!(
            "env:  CE = ({} nodes, {} cores, {}), {} thread(s)/node\n",
            p.nodes,
            p.cores_per_node,
            fmt_bytes(p.max_mem),
            p.threads_per_node
        ));
        out.push_str(&format!(
            "partitions: {} ({} misc), max size {}\n",
            self.n_partitions(),
            self.n_misc_partitions(),
            self.partitions.max_size()
        ));
        out.push_str(&format!(
            "tasks: {} / {} pair comparisons; skew: max {} vs mean \
             {:.0} pairs (ratio {:.2}); max task memory {}",
            s.n_tasks,
            s.total_pairs,
            s.max_pairs,
            s.mean_pairs,
            s.skew_ratio,
            fmt_bytes(s.max_task_mem)
        ));
        out
    }

    // -------------------------------------------------- serialization

    /// Serialize to the canonical byte format (see module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(
            64 + self.tasks.len() * 20
                + self.partitions.total_entities() * 4,
        );
        b.extend_from_slice(PLAN_MAGIC);
        let p = &self.provenance;
        put_str(&mut b, &p.strategy);
        put_str(&mut b, &p.params);
        b.push(match p.match_kind {
            StrategyKind::Wam => 0,
            StrategyKind::Lrm => 1,
        });
        put_u64(&mut b, p.dataset_entities);
        put_u64(&mut b, p.dataset_fingerprint);
        put_u32(&mut b, p.nodes);
        put_u32(&mut b, p.cores_per_node);
        put_u32(&mut b, p.threads_per_node);
        put_u64(&mut b, p.max_mem);
        put_u32(&mut b, self.partitions.len() as u32);
        for part in self.partitions.iter() {
            put_kind(&mut b, &part.kind);
            put_u32(&mut b, part.entities.len() as u32);
            for id in &part.entities {
                put_u32(&mut b, id.0);
            }
        }
        put_u32(&mut b, self.tasks.len() as u32);
        for t in &self.tasks {
            put_u32(&mut b, t.id);
            put_u32(&mut b, t.left.0);
            put_u32(&mut b, t.right.0);
        }
        debug_assert_eq!(self.task_mem.len(), self.tasks.len());
        for &m in &self.task_mem {
            put_u64(&mut b, m);
        }
        b
    }

    /// Deserialize a plan written by [`MatchPlan::to_bytes`].  Strict:
    /// bad magic, truncation or trailing bytes are errors.
    pub fn from_bytes(bytes: &[u8]) -> Result<MatchPlan> {
        let mut d = PlanDec {
            buf: bytes,
            pos: 0,
        };
        let magic = d.take(PLAN_MAGIC.len())?;
        if magic != PLAN_MAGIC {
            bail!("not a pem plan (bad magic)");
        }
        let strategy = d.string()?;
        let params = d.string()?;
        let match_kind = match d.u8()? {
            0 => StrategyKind::Wam,
            1 => StrategyKind::Lrm,
            other => bail!("unknown match-strategy tag {other}"),
        };
        let dataset_entities = d.u64()?;
        let dataset_fingerprint = d.u64()?;
        let nodes = d.u32()?;
        let cores_per_node = d.u32()?;
        let threads_per_node = d.u32()?;
        let max_mem = d.u64()?;
        let n_parts = d.len(6)?;
        let mut partitions = PartitionSet::new();
        for i in 0..n_parts {
            let kind = d.kind()?;
            let n = d.len(4)?;
            let mut entities = Vec::with_capacity(n);
            for _ in 0..n {
                entities.push(crate::model::EntityId(d.u32()?));
            }
            let id = partitions.push(kind, entities);
            if id.0 as usize != i {
                bail!("partition ids out of order in plan");
            }
        }
        let n_tasks = d.len(12)?;
        let mut tasks = Vec::with_capacity(n_tasks);
        for _ in 0..n_tasks {
            let id = d.u32()?;
            let left = PartitionId(d.u32()?);
            let right = PartitionId(d.u32()?);
            if left.0 as usize >= n_parts || right.0 as usize >= n_parts
            {
                bail!("task {id} references unknown partition");
            }
            tasks.push(MatchTask { id, left, right });
        }
        let mut task_mem = Vec::with_capacity(n_tasks);
        for _ in 0..n_tasks {
            task_mem.push(d.u64()?);
        }
        d.finish()?;
        Ok(MatchPlan {
            provenance: PlanProvenance {
                strategy,
                params,
                match_kind,
                dataset_entities,
                dataset_fingerprint,
                nodes,
                cores_per_node,
                threads_per_node,
                max_mem,
            },
            partitions,
            tasks,
            task_mem,
        })
    }
}

// ------------------------------------------------- codec primitives
// (the u32/u64/string encoders are the rpc module's — one set of
// primitives for both canonical binary formats)

use crate::rpc::{put_str, put_u32, put_u64};

fn put_kind(b: &mut Vec<u8>, kind: &PartitionKind) {
    match kind {
        PartitionKind::SizeBased => b.push(0),
        PartitionKind::Block { key } => {
            b.push(1);
            put_str(b, key);
        }
        PartitionKind::SubBlock { key, index, count } => {
            b.push(2);
            put_str(b, key);
            put_u32(b, *index as u32);
            put_u32(b, *count as u32);
        }
        PartitionKind::Aggregate { keys } => {
            b.push(3);
            put_u32(b, keys.len() as u32);
            for k in keys {
                put_str(b, k);
            }
        }
        PartitionKind::Misc { index, count } => {
            b.push(4);
            put_u32(b, *index as u32);
            put_u32(b, *count as u32);
        }
        PartitionKind::Window { index, count } => {
            b.push(5);
            put_u32(b, *index as u32);
            put_u32(b, *count as u32);
        }
    }
}

struct PlanDec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> PlanDec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!("truncated plan");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A count whose elements need at least `min_elem_bytes` each,
    /// validated against the remaining buffer before allocation.
    fn len(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_elem_bytes) > self.buf.len() - self.pos {
            bail!("truncated plan (lying count)");
        }
        Ok(n)
    }

    fn string(&mut self) -> Result<String> {
        let n = self.len(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| anyhow::anyhow!("plan string is not UTF-8"))
    }

    fn kind(&mut self) -> Result<PartitionKind> {
        Ok(match self.u8()? {
            0 => PartitionKind::SizeBased,
            1 => PartitionKind::Block {
                key: self.string()?,
            },
            2 => PartitionKind::SubBlock {
                key: self.string()?,
                index: self.u32()? as usize,
                count: self.u32()? as usize,
            },
            3 => {
                let n = self.len(4)?;
                let mut keys = Vec::with_capacity(n);
                for _ in 0..n {
                    keys.push(self.string()?);
                }
                PartitionKind::Aggregate { keys }
            }
            4 => PartitionKind::Misc {
                index: self.u32()? as usize,
                count: self.u32()? as usize,
            },
            5 => PartitionKind::Window {
                index: self.u32()? as usize,
                count: self.u32()? as usize,
            },
            other => bail!("unknown partition-kind tag {other}"),
        })
    }

    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "{} trailing bytes after plan",
                self.buf.len() - self.pos
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::partition::{BlockingBased, SizeBased, SortedNeighborhood};
    use crate::util::GIB;

    fn ce() -> ComputingEnv {
        ComputingEnv::new(2, 2, GIB)
    }

    #[test]
    fn build_and_inspect_size_based_plan() {
        let data = GeneratorConfig::tiny().with_entities(400).generate();
        let plan = MatchPlan::build(
            &data.dataset,
            &SizeBased::with_max_size(100),
            StrategyKind::Wam,
            &ce(),
        )
        .unwrap();
        assert_eq!(plan.n_partitions(), 4);
        assert_eq!(plan.n_tasks(), 4 + 4 * 3 / 2);
        assert_eq!(plan.total_pairs(), 400 * 399 / 2);
        assert_eq!(plan.task_mem.len(), plan.n_tasks());
        // Cartesian tasks over equal partitions: near-zero skew (intra
        // tasks are half the pairs of cross tasks)
        let skew = plan.skew();
        assert_eq!(skew.total_pairs, 400 * 399 / 2);
        assert!(skew.skew_ratio < 1.5, "ratio {}", skew.skew_ratio);
        assert!(skew.max_task_mem >= 20 * 100 * 100);
        assert!(plan.matches_dataset(&data.dataset));
        assert!(!plan.summary().is_empty());
        let top = plan.top_tasks(3);
        assert_eq!(top.len(), 3);
        assert!(top[0].1 >= top[1].1 && top[1].1 >= top[2].1);
    }

    #[test]
    fn serialization_roundtrips_byte_identical() {
        let data = GeneratorConfig::tiny().with_entities(600).generate();
        for strategy in [
            Box::new(SizeBased::with_max_size(150))
                as Box<dyn PartitionStrategy>,
            Box::new(
                BlockingBased::product_type().with_bounds(150, 30),
            ),
            Box::new(
                SortedNeighborhood::by_title(40).with_max_size(120),
            ),
        ] {
            let plan = MatchPlan::build(
                &data.dataset,
                strategy.as_ref(),
                StrategyKind::Lrm,
                &ce(),
            )
            .unwrap();
            let bytes = plan.to_bytes();
            let back = MatchPlan::from_bytes(&bytes).unwrap();
            assert_eq!(back.to_bytes(), bytes, "{}", strategy.name());
            assert_eq!(back.provenance, plan.provenance);
            assert_eq!(back.tasks, plan.tasks);
            assert_eq!(back.task_mem, plan.task_mem);
        }
    }

    /// The NaN satellite: a plan over an empty dataset (or one whose
    /// blocking yields no pairs) must report finite skew stats — NaN
    /// would poison `pem plan` output and everything serialized from
    /// it.
    #[test]
    fn empty_plan_skew_is_finite_not_nan() {
        use crate::model::{Dataset, Schema, ATTR_TITLE};
        let ds = Dataset::new(Schema::new(vec![ATTR_TITLE]));
        let plan = MatchPlan::build(
            &ds,
            &SizeBased::with_max_size(10),
            StrategyKind::Wam,
            &ce(),
        )
        .unwrap();
        assert_eq!(plan.n_tasks(), 0);
        assert_eq!(plan.n_partitions(), 0);
        let s = plan.skew();
        assert!(s.mean_pairs.is_finite(), "mean {}", s.mean_pairs);
        assert!(s.skew_ratio.is_finite(), "ratio {}", s.skew_ratio);
        assert_eq!(s.mean_pairs, 0.0);
        assert_eq!(s.skew_ratio, 1.0);
        assert_eq!(s.total_pairs, 0);
        let summary = plan.summary();
        assert!(!summary.contains("NaN"), "summary: {summary}");
        // the empty plan still serializes canonically
        let bytes = plan.to_bytes();
        assert_eq!(
            MatchPlan::from_bytes(&bytes).unwrap().to_bytes(),
            bytes
        );
        assert!(plan.task_sizes().is_empty());
    }

    #[test]
    fn task_sizes_mirror_partition_lengths() {
        let data = GeneratorConfig::tiny().with_entities(250).generate();
        let plan = MatchPlan::build(
            &data.dataset,
            &SizeBased::with_max_size(100),
            StrategyKind::Wam,
            &ce(),
        )
        .unwrap();
        let sizes = plan.task_sizes();
        assert_eq!(sizes.len(), plan.n_tasks());
        for t in &plan.tasks {
            let &(l, r) = sizes.get(&t.id).unwrap();
            assert_eq!(l as usize, plan.partitions.get(t.left).len());
            assert_eq!(r as usize, plan.partitions.get(t.right).len());
        }
    }

    #[test]
    fn corrupt_plans_rejected() {
        let data = GeneratorConfig::tiny().with_entities(100).generate();
        let plan = MatchPlan::build(
            &data.dataset,
            &SizeBased::with_max_size(50),
            StrategyKind::Wam,
            &ce(),
        )
        .unwrap();
        let bytes = plan.to_bytes();
        assert!(MatchPlan::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(MatchPlan::from_bytes(&bad_magic).is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(MatchPlan::from_bytes(&trailing).is_err());
        assert!(MatchPlan::from_bytes(b"").is_err());
    }

    /// Same entity ids, different attribute values: the fingerprint
    /// must change (a plan over stale content is not executable).
    #[test]
    fn fingerprint_detects_changed_attribute_values() {
        use crate::model::{
            Dataset, Entity, EntityId, Schema, ATTR_TITLE,
        };
        let schema = Schema::new(vec![ATTR_TITLE]);
        let mk = |title: &str| {
            let mut ds = Dataset::new(schema.clone());
            let mut e = Entity::new(EntityId(0), &schema);
            e.set(&schema, ATTR_TITLE, title.to_string());
            ds.push(e);
            ds
        };
        assert_ne!(
            dataset_fingerprint(&mk("samsung f1")),
            dataset_fingerprint(&mk("samsung f2")),
            "content drift must change the fingerprint"
        );
    }

    #[test]
    fn fingerprint_detects_other_dataset() {
        let a = GeneratorConfig::tiny().with_entities(100).generate();
        let b = GeneratorConfig::tiny().with_entities(101).generate();
        let plan = MatchPlan::build(
            &a.dataset,
            &SizeBased::with_max_size(50),
            StrategyKind::Wam,
            &ce(),
        )
        .unwrap();
        assert!(plan.matches_dataset(&a.dataset));
        assert!(!plan.matches_dataset(&b.dataset));
    }
}
