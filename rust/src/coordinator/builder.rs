//! The composable workflow builder: plan, inspect, then execute.
//!
//! ```no_run
//! use pem::coordinator::Workflow;
//! use pem::engine::backend::{Dist, DistOptions};
//! use pem::partition::SortedNeighborhood;
//!
//! # fn main() -> anyhow::Result<()> {
//! let data = pem::datagen::GeneratorConfig::small().generate();
//! let planned = Workflow::for_dataset(&data.dataset)
//!     .strategy(SortedNeighborhood::by_title(200))
//!     .backend(Dist(DistOptions { replicas: 2, batch: 4, ..Default::default() }))
//!     .env(pem::cluster::ComputingEnv::new(2, 2, 3 * pem::util::GIB))
//!     .cache(16)
//!     .plan()?;           // ← stop here to inspect task skew…
//! println!("{}", planned.plan().summary());
//! let outcome = planned.execute()?;   // …or pay for execution
//! println!("{} matches", outcome.result.len());
//! # Ok(())
//! # }
//! ```
//!
//! The split mirrors the paper's Figure-1 pipeline: `.plan()` runs the
//! cheap pre-processing half (blocking/partitioning + task generation)
//! and returns a [`PlannedWorkflow`] holding an inspectable
//! [`MatchPlan`]; `.execute()` hands that plan to the configured
//! [`ExecutionBackend`].  Strategies and backends are open traits —
//! see [`crate::partition::strategy`] and [`crate::engine::backend`].

use crate::cluster::ComputingEnv;
use crate::coordinator::plan::MatchPlan;
use crate::coordinator::scheduler::Policy;
use crate::engine::backend::{
    ExecContext, ExecutionBackend, Threads,
};
use crate::engine::CostParams;
use crate::matching::{MatchStrategy, StrategyKind};
use crate::metrics::RunMetrics;
use crate::model::{Dataset, MatchResult};
use crate::obs::{system_clock, Clock, Tracer};
use crate::partition::{BlockingBased, PartitionStrategy};
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Duration;

/// Outcome of an executed workflow: merged result + run metrics +
/// structural info from the plan.
pub struct RunOutcome {
    /// Merged, deduplicated correspondences.
    pub result: MatchResult,
    /// Engine metrics (wall clock or virtual time, see engine docs).
    pub metrics: RunMetrics,
    /// Partitions after tuning.
    pub n_partitions: usize,
    /// Partitions that came from the misc block (§3.2).
    pub n_misc_partitions: usize,
    /// Match tasks generated.
    pub n_tasks: usize,
    /// Wall-clock time of the whole workflow (plan + match + merge
    /// when run through [`Workflow::run`]; execution + merge when the
    /// plan was built separately).
    pub elapsed: std::time::Duration,
    /// Cost params used by the simulator (after calibration).
    pub cost: Option<CostParams>,
}

/// Fluent builder for a match workflow (see module docs).
pub struct Workflow<'a> {
    dataset: &'a Dataset,
    strategy: Box<dyn PartitionStrategy>,
    backend: Box<dyn ExecutionBackend>,
    matching: MatchStrategy,
    ce: ComputingEnv,
    cache_capacity: usize,
    policy: Policy,
    tracer: Option<Arc<Tracer>>,
    clock: Arc<dyn Clock>,
}

impl<'a> Workflow<'a> {
    /// Start a workflow over `dataset` with the paper's defaults:
    /// blocking-based partitioning by product type, WAM matching, the
    /// [`Threads`] backend, one 4-core node, affinity scheduling, no
    /// cache.
    pub fn for_dataset(dataset: &'a Dataset) -> Workflow<'a> {
        Workflow {
            dataset,
            strategy: Box::new(BlockingBased::product_type()),
            backend: Box::new(Threads),
            matching: MatchStrategy::new(StrategyKind::Wam),
            ce: ComputingEnv::new(1, 4, 3 * crate::util::GIB),
            cache_capacity: 0,
            policy: Policy::Affinity,
            tracer: None,
            clock: system_clock(),
        }
    }

    /// Select the partitioning strategy.
    pub fn strategy(
        self,
        strategy: impl PartitionStrategy + 'static,
    ) -> Self {
        self.strategy_boxed(Box::new(strategy))
    }

    /// Select an already-boxed partitioning strategy (for callers that
    /// choose at run time, like the CLI).
    pub fn strategy_boxed(
        mut self,
        strategy: Box<dyn PartitionStrategy>,
    ) -> Self {
        self.strategy = strategy;
        self
    }

    /// Select the execution backend.
    pub fn backend(
        self,
        backend: impl ExecutionBackend + 'static,
    ) -> Self {
        self.backend_boxed(Box::new(backend))
    }

    /// Select an already-boxed execution backend.
    pub fn backend_boxed(
        mut self,
        backend: Box<dyn ExecutionBackend>,
    ) -> Self {
        self.backend = backend;
        self
    }

    /// Select the match strategy by kind (default threshold).
    pub fn matching(mut self, kind: StrategyKind) -> Self {
        self.matching = MatchStrategy::new(kind);
        self
    }

    /// Select a fully-configured match strategy.
    pub fn match_strategy(mut self, strategy: MatchStrategy) -> Self {
        self.matching = strategy;
        self
    }

    /// Set the computing environment the plan is sized for and the
    /// backend executes on.
    pub fn env(mut self, ce: ComputingEnv) -> Self {
        self.ce = ce;
        self
    }

    /// Set the per-service partition-cache capacity (`c`; 0 disables).
    pub fn cache(mut self, capacity: usize) -> Self {
        self.cache_capacity = capacity;
        self
    }

    /// Set the task-assignment policy.
    pub fn policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Attach a lifecycle [`Tracer`]: the backend's scheduler and
    /// workers record every task's `Planned → … → Completed` history
    /// into it.  Keep the `Arc` — after [`PlannedWorkflow::execute`]
    /// returns, dump it ([`Tracer::dump_jsonl`]) or replay-verify it
    /// ([`Tracer::verify_plan`]).  The sim backend ignores tracing.
    pub fn trace(mut self, tracer: Arc<Tracer>) -> Self {
        self.tracer = Some(tracer);
        self
    }

    /// Inject the clock that times the run (`RunOutcome::elapsed`).
    /// Defaults to [`system_clock`]; tests pass a
    /// [`crate::obs::ManualClock`] to make elapsed time deterministic.
    pub fn clock(mut self, clock: Arc<dyn Clock>) -> Self {
        self.clock = clock;
        self
    }

    /// Run the planning half: partitioning + task generation + memory
    /// footprints.  Cheap; no matching happens.
    pub fn plan(self) -> Result<PlannedWorkflow<'a>> {
        let plan = MatchPlan::build(
            self.dataset,
            self.strategy.as_ref(),
            self.matching.kind,
            &self.ce,
        )?;
        Ok(PlannedWorkflow {
            plan,
            dataset: self.dataset,
            backend: self.backend,
            matching: self.matching,
            ce: self.ce,
            cache_capacity: self.cache_capacity,
            policy: self.policy,
            tracer: self.tracer,
            clock: self.clock,
        })
    }

    /// Plan and execute in one call, timing the whole pipeline.
    pub fn run(self) -> Result<RunOutcome> {
        let clock = Arc::clone(&self.clock);
        let t0 = clock.now_ns();
        let mut out = self.plan()?.execute()?;
        out.elapsed = Duration::from_nanos(clock.now_ns().saturating_sub(t0));
        Ok(out)
    }
}

/// A planned workflow: the [`MatchPlan`] plus everything needed to
/// execute it.  Inspect the plan (print, serialize, check skew), then
/// call [`PlannedWorkflow::execute`].
pub struct PlannedWorkflow<'a> {
    plan: MatchPlan,
    dataset: &'a Dataset,
    backend: Box<dyn ExecutionBackend>,
    matching: MatchStrategy,
    ce: ComputingEnv,
    cache_capacity: usize,
    policy: Policy,
    tracer: Option<Arc<Tracer>>,
    clock: Arc<dyn Clock>,
}

impl<'a> PlannedWorkflow<'a> {
    /// The plan artifact.
    pub fn plan(&self) -> &MatchPlan {
        &self.plan
    }

    /// Give up the plan without executing (e.g. to serialize it).
    pub fn into_plan(self) -> MatchPlan {
        self.plan
    }

    /// Execute the plan on the configured backend and merge the
    /// per-task outputs (the workflow service's post-processing).
    pub fn execute(self) -> Result<RunOutcome> {
        let t0 = self.clock.now_ns();
        if !self.plan.matches_dataset(self.dataset) {
            bail!(
                "plan was built for a different dataset (fingerprint \
                 mismatch)"
            );
        }
        let ctx = ExecContext {
            dataset: self.dataset,
            ce: &self.ce,
            strategy: self.matching,
            cache_capacity: self.cache_capacity,
            policy: self.policy,
            tracer: self.tracer.clone(),
        };
        let run = self.backend.execute(&self.plan, &ctx)?;
        let mut result = MatchResult::new();
        for c in run.correspondences {
            result.add(c);
        }
        Ok(RunOutcome {
            result,
            metrics: run.metrics,
            n_partitions: self.plan.n_partitions(),
            n_misc_partitions: self.plan.n_misc_partitions(),
            n_tasks: self.plan.n_tasks(),
            elapsed: Duration::from_nanos(
                self.clock.now_ns().saturating_sub(t0),
            ),
            cost: run.cost,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::engine::backend::{Sim, SimOptions};
    use crate::partition::{SizeBased, SortedNeighborhood};
    use crate::util::GIB;

    #[test]
    fn plan_then_execute_finds_duplicates() {
        let data = GeneratorConfig::tiny().with_seed(21).generate();
        let planned = Workflow::for_dataset(&data.dataset)
            .strategy(SizeBased::auto())
            .backend(Threads)
            .env(ComputingEnv::new(1, 2, GIB))
            .plan()
            .unwrap();
        assert!(planned.plan().n_tasks() >= planned.plan().n_partitions());
        let out = planned.execute().unwrap();
        let q = out.result.quality(&data.truth);
        assert!(q.recall > 0.8, "recall {}", q.recall);
        assert!(q.precision > 0.5, "precision {}", q.precision);
    }

    #[test]
    fn sorted_neighborhood_prunes_comparisons_but_keeps_recall() {
        let data = GeneratorConfig::tiny().with_entities(900).generate();
        let ce = ComputingEnv::new(1, 2, GIB);
        let cartesian = Workflow::for_dataset(&data.dataset)
            .strategy(SizeBased::with_max_size(150))
            .backend(Threads)
            .env(ce)
            .run()
            .unwrap();
        let sn = Workflow::for_dataset(&data.dataset)
            .strategy(
                SortedNeighborhood::by_title(80).with_max_size(150),
            )
            .backend(Threads)
            .env(ce)
            .run()
            .unwrap();
        assert!(
            sn.metrics.comparisons < cartesian.metrics.comparisons / 2,
            "sn {} vs cartesian {}",
            sn.metrics.comparisons,
            cartesian.metrics.comparisons
        );
        // same floor as the sorted-neighborhood blocking operator's
        // integration test: windowing trades some recall for pruning
        let qs = sn.result.quality(&data.truth);
        assert!(qs.recall > 0.4, "sn recall {}", qs.recall);
    }

    #[test]
    fn executing_against_the_wrong_dataset_is_refused() {
        let a = GeneratorConfig::tiny().with_entities(200).generate();
        let b = GeneratorConfig::tiny().with_entities(300).generate();
        let planned = Workflow::for_dataset(&a.dataset)
            .strategy(SizeBased::with_max_size(50))
            .env(ComputingEnv::new(1, 2, GIB))
            .plan()
            .unwrap();
        // swap the dataset behind the plan's back
        let hijacked = PlannedWorkflow {
            dataset: &b.dataset,
            ..planned
        };
        assert!(hijacked.execute().is_err());
    }

    #[test]
    fn sim_backend_through_builder_reports_cost() {
        let data = GeneratorConfig::tiny().generate();
        let out = Workflow::for_dataset(&data.dataset)
            .matching(StrategyKind::Lrm)
            .backend(Sim(SimOptions {
                calibrate: false,
                ..SimOptions::default()
            }))
            .env(ComputingEnv::paper_testbed(4))
            .run()
            .unwrap();
        assert!(out.metrics.makespan_ns > 0);
        assert_eq!(out.result.len(), 0, "sim without execute");
        assert!(out.cost.is_some());
    }

    /// The run timer is injectable (PR 10: builder timing moved onto
    /// the `Clock` trait): a `ManualClock` that never advances yields
    /// a zero `elapsed`, proving no hidden `Instant::now()` remains
    /// on the path.
    #[test]
    fn run_timing_reads_the_injected_clock() {
        let data = GeneratorConfig::tiny().with_entities(120).generate();
        let frozen = Arc::new(crate::obs::ManualClock::new(5_000));
        let out = Workflow::for_dataset(&data.dataset)
            .strategy(SizeBased::with_max_size(40))
            .backend(Threads)
            .env(ComputingEnv::new(1, 1, GIB))
            .clock(frozen)
            .run()
            .unwrap();
        assert_eq!(out.elapsed, std::time::Duration::ZERO);
    }
}
