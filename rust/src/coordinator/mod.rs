//! The workflow service (paper §4): end-to-end match workflow execution.
//!
//! The workflow service is the central access point.  Since the
//! plan/execute split it is layered as:
//!
//! * [`builder`] — the fluent [`Workflow`] builder: pick a
//!   [`PartitionStrategy`](crate::partition::PartitionStrategy), an
//!   [`ExecutionBackend`](crate::engine::backend::ExecutionBackend),
//!   the shared service knobs, then `.plan()` and `.execute()`;
//! * [`plan`] — the inspectable, serializable [`MatchPlan`] artifact
//!   the planning half produces (partitions + tasks + §3.1 memory
//!   footprints + provenance);
//! * [`scheduler`] — the central task list and affinity-based
//!   scheduling the execution half runs on;
//! * [`workflow`] — the legacy [`WorkflowConfig`] shim (deprecated;
//!   `docs/MIGRATION.md` maps it onto the builder);
//! * [`multi_source`] — the §3.3 multi-source workflow variants.

#![warn(missing_docs)]

pub mod builder;
pub mod multi_source;
pub mod plan;
pub mod scheduler;
pub mod workflow;

pub use builder::{PlannedWorkflow, RunOutcome, Workflow};
pub use multi_source::{run_two_source_workflow, TwoSourceMode};
pub use plan::{MatchPlan, PlanProvenance, PlanSkew};
pub use scheduler::{PlanMisfit, Policy, Scheduler, ServiceId};
pub use workflow::{
    run_workflow, PartitioningChoice, WorkflowConfig, WorkflowOutcome,
};
