//! The workflow service (paper §4): end-to-end match workflow execution.
//!
//! The workflow service is the central access point: it performs the
//! pre-processing (blocking, partitioning, match task generation),
//! maintains the central task list and the affinity-based scheduler
//! ([`scheduler`]), drives one of the execution engines, and merges the
//! per-task match results into the final output ([`workflow`]).

#![warn(missing_docs)]

pub mod multi_source;
pub mod scheduler;
pub mod workflow;

pub use multi_source::{run_two_source_workflow, TwoSourceMode};
pub use scheduler::{Policy, Scheduler, ServiceId};
pub use workflow::{
    run_workflow, PartitioningChoice, WorkflowConfig, WorkflowOutcome,
};
