//! Feature extraction: q-grams, token sets and hashed feature vectors.
//!
//! Two consumers:
//!
//! * the pure-Rust matchers ([`crate::matching`]) work on exact q-gram /
//!   token multisets ([`QGramSet`], [`TokenSet`]);
//! * the accelerated PJRT path works on **hashed** fixed-dimension count
//!   vectors assembled into padded partition matrices ([`FeatureMatrix`])
//!   — the `f32[M, D]` inputs of the Layer-1 Pallas kernel.
//!
//! Hashing uses FNV-1a so Rust and any other producer agree on buckets.

use crate::model::{Dataset, Entity};
use crate::util::fnv1a;

/// Default q for q-grams (trigrams, as in the paper's TriGram matcher).
pub const DEFAULT_Q: usize = 3;

/// Default hashed feature dimension (matches `python/compile/aot.py`).
pub const DEFAULT_DIM: usize = 256;

/// Normalize a string for matching: lowercase, collapse whitespace.
pub fn normalize(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut last_space = true;
    for c in s.chars() {
        if c.is_whitespace() {
            if !last_space {
                out.push(' ');
                last_space = true;
            }
        } else {
            for lc in c.to_lowercase() {
                out.push(lc);
            }
            last_space = false;
        }
    }
    while out.ends_with(' ') {
        out.pop();
    }
    out
}

/// Sorted multiset of q-grams of a padded, normalized string.
///
/// Padding with `q-1` boundary markers (`#`) gives terminal characters the
/// same weight as interior ones — standard for q-gram string similarity.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QGramSet {
    grams: Vec<u64>, // fnv1a hashes of the grams, sorted (multiset)
}

impl QGramSet {
    pub fn new(s: &str, q: usize) -> QGramSet {
        assert!(q >= 1);
        let norm = normalize(s);
        let padded: Vec<char> = std::iter::repeat('#')
            .take(q - 1)
            .chain(norm.chars())
            .chain(std::iter::repeat('#').take(q - 1))
            .collect();
        let mut grams: Vec<u64> = if padded.len() < q {
            Vec::new()
        } else {
            (0..=padded.len() - q)
                .map(|i| {
                    let g: String = padded[i..i + q].iter().collect();
                    fnv1a(g.as_bytes())
                })
                .collect()
        };
        grams.sort_unstable();
        QGramSet { grams }
    }

    /// Rebuild from raw gram hashes (wire decoding — see [`crate::rpc`]).
    /// Sorting restores the canonical multiset representation whatever
    /// order the bytes arrived in.
    pub fn from_hashes(mut grams: Vec<u64>) -> QGramSet {
        grams.sort_unstable();
        QGramSet { grams }
    }

    /// The sorted gram-hash multiset (wire encoding).
    pub fn hashes(&self) -> &[u64] {
        &self.grams
    }

    pub fn len(&self) -> usize {
        self.grams.len()
    }

    pub fn is_empty(&self) -> bool {
        self.grams.is_empty()
    }

    /// Multiset intersection size (sorted-merge).
    pub fn intersection_size(&self, other: &QGramSet) -> usize {
        let (mut i, mut j, mut n) = (0, 0, 0);
        while i < self.grams.len() && j < other.grams.len() {
            match self.grams[i].cmp(&other.grams[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    n += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        n
    }

    /// Fold into a hashed count vector of dimension `dim`.
    pub fn hashed_counts(&self, dim: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; dim];
        for &g in &self.grams {
            v[(g % dim as u64) as usize] += 1.0;
        }
        v
    }

    /// Collapse the sorted multiset into an exact sparse count vector
    /// (unique gram → count).  No hash-bucket collisions; the §Perf
    /// representation for cosine (sorted-merge dot product).
    pub fn to_sparse(&self) -> SparseCounts {
        let mut keys = Vec::new();
        let mut counts: Vec<f32> = Vec::new();
        for &g in &self.grams {
            match keys.last() {
                Some(&last) if last == g => {
                    *counts.last_mut().unwrap() += 1.0;
                }
                _ => {
                    keys.push(g);
                    counts.push(1.0);
                }
            }
        }
        let normsq = counts.iter().map(|c| c * c).sum::<f32>();
        SparseCounts {
            keys,
            counts,
            normsq,
        }
    }
}

/// Exact sparse count vector over gram hashes (sorted unique keys).
#[derive(Clone, Debug, PartialEq)]
pub struct SparseCounts {
    pub keys: Vec<u64>,
    pub counts: Vec<f32>,
    /// Squared L2 norm of the counts.
    pub normsq: f32,
}

impl SparseCounts {
    /// Dot product via sorted merge — O(nnz_a + nnz_b), no allocation.
    pub fn dot(&self, other: &SparseCounts) -> f64 {
        let (mut i, mut j) = (0, 0);
        let mut dot = 0.0f64;
        while i < self.keys.len() && j < other.keys.len() {
            match self.keys[i].cmp(&other.keys[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    dot += (self.counts[i] * other.counts[j]) as f64;
                    i += 1;
                    j += 1;
                }
            }
        }
        dot
    }

    pub fn nnz(&self) -> usize {
        self.keys.len()
    }
}

/// Whitespace token set (for Jaccard on titles).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TokenSet {
    tokens: Vec<u64>, // sorted, deduplicated token hashes
}

impl TokenSet {
    pub fn new(s: &str) -> TokenSet {
        let norm = normalize(s);
        let mut tokens: Vec<u64> = norm
            .split(' ')
            .filter(|t| !t.is_empty())
            .map(|t| fnv1a(t.as_bytes()))
            .collect();
        tokens.sort_unstable();
        tokens.dedup();
        TokenSet { tokens }
    }

    /// Rebuild from raw token hashes (wire decoding — see [`crate::rpc`]).
    pub fn from_hashes(mut tokens: Vec<u64>) -> TokenSet {
        tokens.sort_unstable();
        tokens.dedup();
        TokenSet { tokens }
    }

    /// The sorted, deduplicated token hashes (wire encoding).
    pub fn hashes(&self) -> &[u64] {
        &self.tokens
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn intersection_size(&self, other: &TokenSet) -> usize {
        let (mut i, mut j, mut n) = (0, 0, 0);
        while i < self.tokens.len() && j < other.tokens.len() {
            match self.tokens[i].cmp(&other.tokens[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    n += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        n
    }
}

/// Precomputed per-entity match features (built once per entity, reused by
/// every match task touching its partition — this is what the data service
/// ships and the partition caches hold).
#[derive(Clone, Debug)]
pub struct EntityFeatures {
    pub title_norm: String,
    /// Normalized title as chars — lets the banded edit distance run
    /// without per-pair `Vec<char>` allocation (§Perf).
    pub title_chars: Vec<char>,
    pub title_grams: QGramSet,
    pub title_tokens: TokenSet,
    pub desc_grams: QGramSet,
    /// Exact sparse gram counts for the cosine matcher (§Perf: replaces
    /// per-pair dense hashed vectors with a sorted-merge dot product).
    pub title_sparse: SparseCounts,
    pub desc_sparse: SparseCounts,
}

impl EntityFeatures {
    pub fn of(entity: &Entity, dataset: &Dataset) -> EntityFeatures {
        let schema = &dataset.schema;
        let title = entity.title(schema);
        let desc = entity.description(schema);
        let title_norm = normalize(title);
        let title_grams = QGramSet::new(title, DEFAULT_Q);
        let desc_grams = QGramSet::new(desc, DEFAULT_Q);
        EntityFeatures {
            title_chars: title_norm.chars().collect(),
            title_norm,
            title_sparse: title_grams.to_sparse(),
            desc_sparse: desc_grams.to_sparse(),
            title_grams,
            title_tokens: TokenSet::new(title),
            desc_grams,
        }
    }

    /// Approximate footprint (bytes) for transfer/memory cost models.
    pub fn approx_bytes(&self) -> usize {
        self.title_norm.len()
            + 4 * self.title_chars.len()
            + 8 * (self.title_grams.len()
                + self.title_tokens.len()
                + self.desc_grams.len())
            + 12 * (self.title_sparse.nnz() + self.desc_sparse.nnz())
            + std::mem::size_of::<EntityFeatures>()
    }
}

/// A padded `f32[M, D]` feature matrix for one attribute of one partition
/// — the exact input layout of the AOT-compiled match executables.
/// Row-major, rows past `rows` are zero (padding).
#[derive(Clone, Debug, PartialEq)]
pub struct FeatureMatrix {
    pub rows: usize,     // real entities
    pub capacity: usize, // padded row count M
    pub dim: usize,      // feature dimension D
    pub data: Vec<f32>,  // capacity * dim, row-major
}

impl FeatureMatrix {
    /// Build from q-gram sets, padding up to `capacity` rows.
    pub fn from_qgrams(
        grams: &[&QGramSet],
        capacity: usize,
        dim: usize,
    ) -> FeatureMatrix {
        assert!(grams.len() <= capacity, "{} > {}", grams.len(), capacity);
        let mut data = vec![0.0f32; capacity * dim];
        for (r, g) in grams.iter().enumerate() {
            data[r * dim..(r + 1) * dim].copy_from_slice(&g.hashed_counts(dim));
        }
        FeatureMatrix {
            rows: grams.len(),
            capacity,
            dim,
            data,
        }
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.dim..(r + 1) * self.dim]
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::Rng;

    #[test]
    fn normalize_basics() {
        assert_eq!(normalize("  LG  GH22NS50 "), "lg gh22ns50");
        assert_eq!(normalize("Ü"), "ü");
        assert_eq!(normalize(""), "");
    }

    #[test]
    fn qgram_count_matches_formula() {
        // padded length = len + 2*(q-1); grams = padded - q + 1 = len + q - 1
        let s = "abcd";
        let g = QGramSet::new(s, 3);
        assert_eq!(g.len(), 4 + 3 - 1);
        let empty = QGramSet::new("", 3);
        // normalize("") = "", padded = "####", grams = 2 (## boundary overlap)
        assert_eq!(empty.len(), 2);
    }

    #[test]
    fn qgram_self_intersection_is_len() {
        let g = QGramSet::new("samsung spinpoint", 3);
        assert_eq!(g.intersection_size(&g), g.len());
    }

    #[test]
    fn qgram_intersection_symmetric_and_bounded() {
        forall("qgram-sym", 100, |rng| {
            let s1 = random_word(rng);
            let s2 = random_word(rng);
            let (a, b) = (QGramSet::new(&s1, 3), QGramSet::new(&s2, 3));
            let i1 = a.intersection_size(&b);
            let i2 = b.intersection_size(&a);
            assert_eq!(i1, i2);
            assert!(i1 <= a.len().min(b.len()));
        });
    }

    fn random_word(rng: &mut Rng) -> String {
        let n = rng.gen_range(12);
        (0..n)
            .map(|_| (b'a' + rng.gen_range(6) as u8) as char)
            .collect()
    }

    #[test]
    fn token_set_dedupes() {
        let t = TokenSet::new("black black USB usb Black");
        assert_eq!(t.len(), 2); // "black", "usb"
    }

    #[test]
    fn hashed_counts_preserve_total() {
        let g = QGramSet::new("western digital caviar", 3);
        let v = g.hashed_counts(64);
        let total: f32 = v.iter().sum();
        assert_eq!(total as usize, g.len());
    }

    #[test]
    fn hashed_intersection_upper_bounds_exact() {
        // min-sum over hashed counts >= exact multiset intersection
        // (hash collisions only ever merge buckets).
        forall("hash-bound", 100, |rng| {
            let s1 = random_word(rng);
            let s2 = random_word(rng);
            let (a, b) = (QGramSet::new(&s1, 3), QGramSet::new(&s2, 3));
            let exact = a.intersection_size(&b) as f32;
            let (va, vb) = (a.hashed_counts(128), b.hashed_counts(128));
            let hashed: f32 =
                va.iter().zip(&vb).map(|(x, y)| x.min(*y)).sum();
            assert!(hashed >= exact - 1e-6, "{hashed} < {exact}");
        });
    }

    #[test]
    fn feature_matrix_layout_and_padding() {
        let g1 = QGramSet::new("ab", 3);
        let g2 = QGramSet::new("cd", 3);
        let m = FeatureMatrix::from_qgrams(&[&g1, &g2], 4, 32);
        assert_eq!(m.rows, 2);
        assert_eq!(m.capacity, 4);
        assert_eq!(m.data.len(), 4 * 32);
        assert!(m.row(2).iter().all(|&x| x == 0.0), "padding zeroed");
        assert!(m.row(0).iter().sum::<f32>() > 0.0);
        assert_eq!(m.bytes(), 4 * 32 * 4);
    }

    #[test]
    #[should_panic]
    fn feature_matrix_overflow_panics() {
        let g = QGramSet::new("x", 3);
        FeatureMatrix::from_qgrams(&[&g, &g, &g], 2, 8);
    }
}
