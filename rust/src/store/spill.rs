//! Out-of-core partition storage: [`SpillStore`], a byte-budgeted hot
//! set in RAM backed by one checksummed spill file per partition.
//!
//! # On-disk format (`part-<id>.spill`, version 1)
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"PEMSPIL1"
//! 8       4     partition id            (u32 LE)
//! 12      8     payload_bytes           (u64 LE, the cost-model size)
//! 20      8     frame_len               (u64 LE)
//! 28      8     FNV-1a 64 of the frame  (u64 LE)
//! 36      …     frame                   (frame_len bytes)
//! ```
//!
//! The `frame` is **exactly** the encoded `Message::Partition` wire
//! frame ([`encode_partition_message`]) — the spill file *is* the
//! bytes the TCP data server ships.  That buys two invariants for
//! free: a fault re-materializes a frame byte-identical to what a
//! resident store would serve (so the zero-copy
//! `SessionEncoder::queue_shared` path is preserved across tiers), and
//! the payload decoded from it round-trips through the same
//! property-tested codec the wire already trusts.  Every fault
//! re-verifies magic, id, length, and checksum before decoding; a
//! mismatch is a typed [`StoreError::Corrupt`], never a panic.

use crate::obs::{Counter, Histogram, Stopwatch};
use crate::partition::PartitionId;
use crate::rpc::{encode_partition_message, Message};
use crate::store::tier::{PartitionStore, StoreError, StoreStats};
use crate::store::PartitionData;
use crate::util::{fnv1a, lock_poisonless, read_poisonless, write_poisonless};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Spill-file magic, bumped with the format.
const SPILL_MAGIC: &[u8; 8] = b"PEMSPIL1";

/// Bytes before the frame: magic + id + payload_bytes + frame_len +
/// checksum.
const SPILL_HEADER_BYTES: usize = 8 + 4 + 8 + 8 + 8;

/// Monotone suffix for generated spill directories, so two stores in
/// one process never collide.
static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// What the index remembers per spilled partition; the payload itself
/// lives on disk (and maybe in the hot set).
struct IndexEntry {
    /// The cost-model size (`PartitionData::approx_bytes`).
    payload_bytes: u64,
    /// On-disk size of the whole spill file.
    file_bytes: u64,
}

/// One hot entry: decoded payload + encoded frame, both shared.
struct HotEntry {
    data: Arc<PartitionData>,
    frame: Arc<Vec<u8>>,
    /// LRU stamp: monotone, bumped on every touch.
    stamp: u64,
}

struct HotSet {
    map: HashMap<PartitionId, HotEntry>,
    /// Sum of hot frame lengths — what the budget caps.
    bytes: u64,
    clock: u64,
}

/// A [`PartitionStore`] whose authority is on disk: every insert is
/// persisted to a spill file, and at most `budget` bytes of frames are
/// kept hot in RAM, evicted LRU.  A `get`/`encoded_frame` miss faults
/// the file back in (verify → decode → re-admit), so a catalog far
/// bigger than the budget still serves — out of core.
pub struct SpillStore {
    dir: PathBuf,
    /// Generated temp dirs are removed on drop; operator-chosen dirs
    /// are left alone.
    owns_dir: bool,
    budget: u64,
    index: RwLock<HashMap<PartitionId, IndexEntry>>,
    hot: Mutex<HotSet>,
    hot_hits: Counter,
    faults: Counter,
    evictions: Counter,
    spill_bytes: AtomicU64,
    fault_ns: Histogram,
}

impl SpillStore {
    /// A spill store keeping at most `budget` hot bytes, spilling to
    /// `dir` (created if missing).  With `dir = None` a unique
    /// directory under the OS temp dir is created and removed when the
    /// store drops.
    pub fn new(
        budget: u64,
        dir: Option<PathBuf>,
    ) -> std::io::Result<SpillStore> {
        let (dir, owns_dir) = match dir {
            Some(d) => (d, false),
            None => {
                let seq = DIR_SEQ.fetch_add(1, Ordering::Relaxed);
                (
                    std::env::temp_dir().join(format!(
                        "pem-spill-{}-{seq}",
                        std::process::id()
                    )),
                    true,
                )
            }
        };
        std::fs::create_dir_all(&dir)?;
        Ok(SpillStore {
            dir,
            owns_dir,
            budget,
            index: RwLock::new(HashMap::new()),
            hot: Mutex::new(HotSet {
                map: HashMap::new(),
                bytes: 0,
                clock: 0,
            }),
            hot_hits: Counter::new(),
            faults: Counter::new(),
            evictions: Counter::new(),
            spill_bytes: AtomicU64::new(0),
            fault_ns: Histogram::new(),
        })
    }

    /// Where this store spills (one `part-<id>.spill` per partition).
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The hot-set byte budget this store was built with.
    pub fn budget(&self) -> u64 {
        self.budget
    }

    fn spill_path(&self, id: PartitionId) -> PathBuf {
        self.dir.join(format!("part-{}.spill", id.0))
    }

    /// Serialize `frame` into its spill-file bytes.
    fn file_bytes(
        id: PartitionId,
        payload_bytes: u64,
        frame: &[u8],
    ) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(SPILL_HEADER_BYTES + frame.len());
        out.extend_from_slice(SPILL_MAGIC);
        out.extend_from_slice(&id.0.to_le_bytes());
        out.extend_from_slice(&payload_bytes.to_le_bytes());
        out.extend_from_slice(&(frame.len() as u64).to_le_bytes());
        out.extend_from_slice(&fnv1a(frame).to_le_bytes());
        out.extend_from_slice(frame);
        out
    }

    /// Touch `id` in the hot set, bumping its LRU stamp.
    fn hot_get(
        &self,
        id: PartitionId,
    ) -> Option<(Arc<PartitionData>, Arc<Vec<u8>>)> {
        let mut hot = lock_poisonless(&self.hot);
        hot.clock += 1;
        let stamp = hot.clock;
        let e = hot.map.get_mut(&id)?;
        e.stamp = stamp;
        self.hot_hits.inc();
        Some((e.data.clone(), e.frame.clone()))
    }

    /// Admit `id` to the hot set, evicting least-recently-used entries
    /// until the budget holds.  A frame larger than the whole budget
    /// is served without being admitted.
    fn admit(
        &self,
        id: PartitionId,
        data: Arc<PartitionData>,
        frame: Arc<Vec<u8>>,
    ) {
        let incoming = frame.len() as u64;
        let mut hot = lock_poisonless(&self.hot);
        if let Some(old) = hot.map.remove(&id) {
            hot.bytes -= old.frame.len() as u64;
        }
        if incoming > self.budget {
            return;
        }
        while hot.bytes + incoming > self.budget {
            let lru = hot
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(&p, _)| p);
            let Some(victim) = lru else { break };
            if let Some(e) = hot.map.remove(&victim) {
                hot.bytes -= e.frame.len() as u64;
                self.evictions.inc();
            }
        }
        hot.clock += 1;
        let stamp = hot.clock;
        hot.bytes += incoming;
        hot.map.insert(id, HotEntry { data, frame, stamp });
    }

    /// Read, verify, and decode the spill file of `id`.
    fn fault(
        &self,
        id: PartitionId,
    ) -> Result<(Arc<PartitionData>, Arc<Vec<u8>>), StoreError> {
        if !read_poisonless(&self.index).contains_key(&id) {
            return Err(StoreError::Unknown(id));
        }
        let t0 = Stopwatch::start();
        let raw = std::fs::read(self.spill_path(id)).map_err(|e| {
            StoreError::Io {
                id,
                detail: e.to_string(),
            }
        })?;
        let corrupt = |detail: &str| StoreError::Corrupt {
            id,
            detail: detail.to_string(),
        };
        if raw.len() < SPILL_HEADER_BYTES {
            return Err(corrupt("file shorter than the header"));
        }
        if &raw[0..8] != SPILL_MAGIC {
            return Err(corrupt("bad magic"));
        }
        let file_id =
            u32::from_le_bytes(raw[8..12].try_into().unwrap());
        if file_id != id.0 {
            return Err(corrupt("partition id mismatch"));
        }
        let frame_len =
            u64::from_le_bytes(raw[20..28].try_into().unwrap()) as usize;
        if raw.len() != SPILL_HEADER_BYTES + frame_len {
            return Err(corrupt("frame length mismatch"));
        }
        let checksum =
            u64::from_le_bytes(raw[28..36].try_into().unwrap());
        let frame = &raw[SPILL_HEADER_BYTES..];
        if fnv1a(frame) != checksum {
            return Err(corrupt("checksum mismatch"));
        }
        let msg = Message::decode(frame)
            .map_err(|e| corrupt(&format!("undecodable frame: {e}")))?;
        let Message::Partition { data } = msg else {
            return Err(corrupt("frame is not a partition message"));
        };
        if data.id != id {
            return Err(corrupt("decoded id mismatch"));
        }
        self.faults.inc();
        self.fault_ns.observe(t0.elapsed_ns());
        let data = Arc::new(data);
        let frame = Arc::new(frame.to_vec());
        self.admit(id, data.clone(), frame.clone());
        Ok((data, frame))
    }
}

impl PartitionStore for SpillStore {
    fn get(
        &self,
        id: PartitionId,
    ) -> Result<Arc<PartitionData>, StoreError> {
        if let Some((data, _)) = self.hot_get(id) {
            return Ok(data);
        }
        self.fault(id).map(|(data, _)| data)
    }

    fn encoded_frame(
        &self,
        id: PartitionId,
    ) -> Result<Arc<Vec<u8>>, StoreError> {
        if let Some((_, frame)) = self.hot_get(id) {
            return Ok(frame);
        }
        self.fault(id).map(|(_, frame)| frame)
    }

    fn payload_bytes(&self, id: PartitionId) -> Option<u64> {
        read_poisonless(&self.index)
            .get(&id)
            .map(|e| e.payload_bytes)
    }

    fn ids(&self) -> Vec<PartitionId> {
        let mut ids: Vec<PartitionId> = read_poisonless(&self.index)
            .keys()
            .copied()
            .collect();
        ids.sort_unstable_by_key(|p| p.0);
        ids
    }

    fn insert(&self, data: Arc<PartitionData>) -> Result<(), StoreError> {
        let id = data.id;
        let frame = Arc::new(encode_partition_message(&data));
        let file =
            Self::file_bytes(id, data.approx_bytes, &frame);
        let file_bytes = file.len() as u64;
        std::fs::write(self.spill_path(id), file).map_err(|e| {
            StoreError::Io {
                id,
                detail: e.to_string(),
            }
        })?;
        let replaced = write_poisonless(&self.index).insert(
            id,
            IndexEntry {
                payload_bytes: data.approx_bytes,
                file_bytes,
            },
        );
        if let Some(old) = replaced {
            self.spill_bytes
                .fetch_sub(old.file_bytes, Ordering::Relaxed);
        }
        self.spill_bytes.fetch_add(file_bytes, Ordering::Relaxed);
        self.admit(id, data, frame);
        Ok(())
    }

    fn stats(&self) -> StoreStats {
        let hot_bytes = lock_poisonless(&self.hot).bytes;
        StoreStats {
            tier: self.tier(),
            hot_hits: self.hot_hits.get(),
            faults: self.faults.get(),
            evictions: self.evictions.get(),
            hot_bytes,
            spill_bytes: self.spill_bytes.load(Ordering::Relaxed),
            fault_ns: self.fault_ns.snapshot(),
        }
    }

    fn tier(&self) -> &'static str {
        "spill"
    }
}

impl Drop for SpillStore {
    fn drop(&mut self) {
        if self.owns_dir {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::model::EntityId;
    use crate::partition::partition_size_based;
    use crate::store::tier::Resident;
    use crate::store::DataService;
    use crate::util::Rng;

    /// A resident reference store and the same payloads in a
    /// `SpillStore` with the given budget.
    fn pair_with(
        entities: usize,
        max: usize,
        budget: u64,
    ) -> (Arc<Resident>, SpillStore, Vec<PartitionId>) {
        let data = GeneratorConfig::tiny()
            .with_entities(entities)
            .generate();
        let ids: Vec<EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let parts = partition_size_based(&ids, max);
        let built = DataService::build(&data.dataset, &parts);
        let resident = Arc::new(Resident::new());
        let spill = SpillStore::new(budget, None).unwrap();
        let mut pids = Vec::new();
        for p in parts.iter() {
            let d = built.fetch(p.id).expect("built partition");
            resident.insert(d.clone()).unwrap();
            spill.insert(d).unwrap();
            pids.push(p.id);
        }
        pids.sort_unstable_by_key(|p| p.0);
        (resident, spill, pids)
    }

    /// The satellite property test: under a tiny budget (forced
    /// eviction on nearly every access), random fetch orders return
    /// payloads and encoded frames **byte-identical** to the resident
    /// store — eviction and re-materialization must be invisible.
    #[test]
    fn spill_random_orders_byte_identical_to_resident() {
        // budget ≈ one partition: almost every get faults from disk
        let (resident, spill, pids) = pair_with(300, 30, 4_096);
        for seed in [1u64, 42, 2010] {
            let mut rng = Rng::new(seed);
            for _ in 0..200 {
                let id = pids[rng.gen_range(pids.len())];
                let want = resident.get(id).unwrap();
                let got = spill.get(id).unwrap();
                assert_eq!(got.id, want.id);
                assert_eq!(got.entities, want.entities);
                assert_eq!(got.approx_bytes, want.approx_bytes);
                assert_eq!(
                    *spill.encoded_frame(id).unwrap(),
                    *resident.encoded_frame(id).unwrap(),
                    "frame differs for {id} (seed {seed})"
                );
            }
        }
        let s = spill.stats();
        assert!(s.faults > 0, "budget never forced a fault");
        assert!(s.evictions > 0, "budget never forced an eviction");
        assert!(s.spill_bytes > 0);
        assert!(s.hot_bytes <= 4_096);
        assert_eq!(s.fault_ns.count, s.faults);
        assert_eq!(spill.ids(), pids);
    }

    #[test]
    fn hot_set_respects_budget_and_serves_hot() {
        let (_, spill, pids) = pair_with(120, 40, u64::MAX >> 1);
        // everything fits hot: repeated gets never fault
        for &id in &pids {
            spill.get(id).unwrap();
            spill.get(id).unwrap();
        }
        let s = spill.stats();
        assert_eq!(s.faults, 0, "inserts pre-warm the hot set");
        assert!(s.hot_hits >= 2 * pids.len() as u64);
        assert!(s.hot_bytes > 0 && s.hot_bytes <= s.spill_bytes);
    }

    #[test]
    fn zero_budget_store_faults_every_access() {
        let (resident, spill, pids) = pair_with(120, 40, 0);
        assert_eq!(spill.stats().hot_bytes, 0);
        for &id in &pids {
            assert_eq!(
                *spill.encoded_frame(id).unwrap(),
                *resident.encoded_frame(id).unwrap()
            );
        }
        let s = spill.stats();
        assert_eq!(s.faults, pids.len() as u64);
        assert_eq!(s.hot_bytes, 0, "nothing may be admitted at 0");
    }

    /// The satellite corruption test: a flipped payload byte, a
    /// truncated file, and a wrong-id header are all rejected with
    /// typed `Corrupt` errors — never served, never a panic.
    #[test]
    fn corrupt_spill_files_are_rejected() {
        let (_, spill, pids) = pair_with(120, 40, 0);
        let id = pids[0];
        let path = spill.spill_path(id);
        let pristine = std::fs::read(&path).unwrap();

        // flip one payload byte: checksum must catch it
        let mut flipped = pristine.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x01;
        std::fs::write(&path, &flipped).unwrap();
        match spill.get(id) {
            Err(StoreError::Corrupt { detail, .. }) => {
                assert!(detail.contains("checksum"), "{detail}")
            }
            other => panic!("flipped byte served: {other:?}"),
        }

        // truncate mid-frame: length check must catch it
        std::fs::write(&path, &pristine[..pristine.len() / 2]).unwrap();
        assert!(matches!(
            spill.get(id),
            Err(StoreError::Corrupt { .. })
        ));

        // a file swapped in from another partition: id check
        let other_path = spill.spill_path(pids[1]);
        std::fs::copy(&other_path, &path).unwrap();
        match spill.get(id) {
            Err(StoreError::Corrupt { detail, .. }) => {
                assert!(detail.contains("id mismatch"), "{detail}")
            }
            other => panic!("swapped file served: {other:?}"),
        }

        // a deleted file is Io, an id never inserted is Unknown
        std::fs::remove_file(&path).unwrap();
        assert!(matches!(spill.get(id), Err(StoreError::Io { .. })));
        assert_eq!(
            spill.get(PartitionId(99_999)).unwrap_err(),
            StoreError::Unknown(PartitionId(99_999))
        );

        // restore: the store serves again (no wedged state)
        std::fs::write(&path, &pristine).unwrap();
        assert_eq!(spill.get(id).unwrap().id, id);
    }

    #[test]
    fn generated_spill_dir_is_removed_on_drop() {
        let (_, spill, pids) = pair_with(80, 40, 1024);
        let dir = spill.dir().to_path_buf();
        assert!(dir.exists());
        assert!(!pids.is_empty());
        drop(spill);
        assert!(!dir.exists(), "owned spill dir must be cleaned up");
    }

    #[test]
    fn operator_dir_survives_drop_and_reinsert_replaces() {
        let base = std::env::temp_dir().join(format!(
            "pem-spill-test-{}-keep",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&base);
        {
            let spill =
                SpillStore::new(1024, Some(base.clone())).unwrap();
            let (_, src, pids) = pair_with(80, 40, 0);
            let d = src.get(pids[0]).unwrap();
            spill.insert(d.clone()).unwrap();
            let before = spill.stats().spill_bytes;
            // re-insert replaces, not double-counts
            spill.insert(d).unwrap();
            assert_eq!(spill.stats().spill_bytes, before);
        }
        assert!(base.exists(), "operator-chosen dir must survive");
        let _ = std::fs::remove_dir_all(&base);
    }
}
