//! Tiered partition storage: the [`PartitionStore`] trait and its
//! in-memory backends.
//!
//! The data plane used to be welded to one layout — every partition's
//! feature payload resident in a `HashMap` for the lifetime of the
//! process, on the primary *and* on every replica.  This module opens
//! that seam: [`DataService`](crate::store::DataService) now fronts an
//! object-safe [`PartitionStore`], and the backend decides where the
//! bytes live:
//!
//! * [`Resident`] — today's behavior: every payload in RAM, plus an
//!   `Arc`-cached encoded wire frame per partition so the TCP fetch
//!   path stays zero-copy ([`SessionEncoder::queue_shared`]).
//! * [`SpillStore`](crate::store::SpillStore) — a byte-budgeted hot
//!   set in RAM backed by per-partition spill files (strict on-disk
//!   format, checksummed); see [`crate::store::spill`].
//! * [`Layered`] — a *partial* hot set over any cold store, admitting
//!   partitions by fetch frequency — the policy partial replicas run
//!   at the frame level (see `service/data.rs`).
//!
//! Every backend serves byte-identical [`PartitionData`] and encoded
//! frames for the same inserts — the spill property tests hold them to
//! that — so swapping tiers can never change a match result.
//!
//! [`SessionEncoder::queue_shared`]: crate::rpc::session::SessionEncoder::queue_shared

use crate::obs::{
    Counter, Histogram, HistogramSnapshot, MetricsSnapshot, Stopwatch,
};
use crate::partition::PartitionId;
use crate::rpc::encode_partition_message;
use crate::store::PartitionData;
use crate::util::{lock_poisonless, read_poisonless, write_poisonless};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

/// Why a store could not produce a partition.  `Unknown` is the benign
/// miss every caller must expect (a malformed remote request, a
/// tenant id from another cluster); `Io`/`Corrupt` mean the spill tier
/// lost or mangled bytes and the payload is *gone*, not just absent.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StoreError {
    /// No partition with this id was ever inserted.
    Unknown(PartitionId),
    /// The backing file could not be read.
    Io {
        /// The partition whose spill file failed.
        id: PartitionId,
        /// OS-level error detail.
        detail: String,
    },
    /// The backing file was read but failed validation (bad magic,
    /// length mismatch, checksum mismatch, undecodable frame).
    Corrupt {
        /// The partition whose spill file failed validation.
        id: PartitionId,
        /// Which check failed.
        detail: String,
    },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Unknown(id) => {
                write!(f, "unknown partition {id}")
            }
            StoreError::Io { id, detail } => {
                write!(f, "partition {id}: spill read failed: {detail}")
            }
            StoreError::Corrupt { id, detail } => {
                write!(f, "partition {id}: spill file corrupt: {detail}")
            }
        }
    }
}

impl std::error::Error for StoreError {}

/// Point-in-time counters of one store tier, exported as `store.*`
/// metrics (see `docs/OBSERVABILITY.md`).
#[derive(Clone, Debug)]
pub struct StoreStats {
    /// Backend name: `resident`, `spill`, or `layered`.
    pub tier: &'static str,
    /// Reads served from the in-memory (hot) set.
    pub hot_hits: u64,
    /// Reads that had to re-materialize a payload from the cold tier.
    pub faults: u64,
    /// Hot-set entries evicted to stay under the byte budget.
    pub evictions: u64,
    /// Bytes currently held hot in RAM.
    pub hot_bytes: u64,
    /// Bytes currently written to spill files on disk.
    pub spill_bytes: u64,
    /// Latency of cold faults (file read + verify + decode), ns.
    pub fault_ns: HistogramSnapshot,
}

impl StoreStats {
    /// Render these stats as a mergeable [`MetricsSnapshot`] under the
    /// `store.*` namespace — the shape `pem stats` scrapes.  Entry
    /// names are emitted pre-sorted, as snapshot consumers require.
    pub fn to_snapshot(&self) -> MetricsSnapshot {
        // metric_name marks the literals for pem-lint's L4 doc
        // cross-check — these names never pass through a Registry
        // instrument call, so the lint cannot see them otherwise
        use crate::obs::metric_name;
        MetricsSnapshot {
            counters: vec![
                (metric_name("store.evictions").into(), self.evictions),
                (metric_name("store.faults").into(), self.faults),
                (metric_name("store.hot_hits").into(), self.hot_hits),
            ],
            gauges: vec![
                (metric_name("store.hot_bytes").into(), self.hot_bytes),
                (
                    metric_name("store.spill_bytes").into(),
                    self.spill_bytes,
                ),
            ],
            histograms: vec![(
                metric_name("store.fault_ns").into(),
                self.fault_ns.clone(),
            )],
            labels: vec![(
                metric_name("store.tier").into(),
                self.tier.to_string(),
            )],
        }
    }
}

/// Object-safe tiered storage for partition payloads.
///
/// Implementations are thread-safe and hand out `Arc`s, so a payload
/// held hot is shared, never copied.  The contract every backend is
/// tested against: for the same inserts, [`get`](PartitionStore::get)
/// returns byte-identical payloads and
/// [`encoded_frame`](PartitionStore::encoded_frame) byte-identical
/// wire frames, whatever evicted in between.
pub trait PartitionStore: Send + Sync {
    /// The payload of `id`, faulting it in from the cold tier if it is
    /// not hot.
    fn get(
        &self,
        id: PartitionId,
    ) -> Result<Arc<PartitionData>, StoreError>;

    /// [`get`](PartitionStore::get) flattened to an `Option` for
    /// callers that treat every failure as a miss.
    fn try_get(&self, id: PartitionId) -> Option<Arc<PartitionData>> {
        self.get(id).ok()
    }

    /// The encoded `Message::Partition` wire frame of `id`, shared by
    /// `Arc` so the TCP serve path writes it without a copy.  Spill
    /// tiers re-materialize the frame on fault — byte-identical, since
    /// the spill file *is* the frame.
    fn encoded_frame(
        &self,
        id: PartitionId,
    ) -> Result<Arc<Vec<u8>>, StoreError>;

    /// Serialized payload size of `id` without faulting it in (the
    /// simulator charges transfer time from this), `None` if unknown.
    fn payload_bytes(&self, id: PartitionId) -> Option<u64>;

    /// All partition ids held (hot or cold), ascending.
    fn ids(&self) -> Vec<PartitionId>;

    /// Insert (or replace) a partition payload.  Spill tiers persist
    /// it before returning; only I/O failure errors.
    fn insert(&self, data: Arc<PartitionData>) -> Result<(), StoreError>;

    /// Current tier counters.
    fn stats(&self) -> StoreStats;

    /// Backend name: `resident`, `spill`, or `layered`.
    fn tier(&self) -> &'static str;
}

// ------------------------------------------------------------------
// Resident
// ------------------------------------------------------------------

/// The classic backend: every payload in RAM for the lifetime of the
/// store, encoded frames cached per partition on first serve.  This is
/// exactly the pre-tiering `DataService` behavior, extracted behind
/// the trait; it never faults and never evicts.
#[derive(Default)]
pub struct Resident {
    partitions: RwLock<HashMap<PartitionId, Arc<PartitionData>>>,
    frames: Mutex<HashMap<PartitionId, Arc<Vec<u8>>>>,
    hot_hits: Counter,
}

impl Resident {
    /// An empty resident store.
    pub fn new() -> Resident {
        Resident::default()
    }
}

impl PartitionStore for Resident {
    fn get(
        &self,
        id: PartitionId,
    ) -> Result<Arc<PartitionData>, StoreError> {
        let data = read_poisonless(&self.partitions)
            .get(&id)
            .cloned()
            .ok_or(StoreError::Unknown(id))?;
        self.hot_hits.inc();
        Ok(data)
    }

    fn encoded_frame(
        &self,
        id: PartitionId,
    ) -> Result<Arc<Vec<u8>>, StoreError> {
        if let Some(frame) = lock_poisonless(&self.frames).get(&id) {
            self.hot_hits.inc();
            return Ok(frame.clone());
        }
        let data = read_poisonless(&self.partitions)
            .get(&id)
            .cloned()
            .ok_or(StoreError::Unknown(id))?;
        let frame = Arc::new(encode_partition_message(&data));
        lock_poisonless(&self.frames).insert(id, frame.clone());
        self.hot_hits.inc();
        Ok(frame)
    }

    fn payload_bytes(&self, id: PartitionId) -> Option<u64> {
        read_poisonless(&self.partitions)
            .get(&id)
            .map(|d| d.approx_bytes)
    }

    fn ids(&self) -> Vec<PartitionId> {
        let mut ids: Vec<PartitionId> = read_poisonless(&self.partitions)
            .keys()
            .copied()
            .collect();
        ids.sort_unstable_by_key(|p| p.0);
        ids
    }

    fn insert(&self, data: Arc<PartitionData>) -> Result<(), StoreError> {
        let id = data.id;
        write_poisonless(&self.partitions).insert(id, data);
        // a replaced payload invalidates its cached frame
        lock_poisonless(&self.frames).remove(&id);
        Ok(())
    }

    fn stats(&self) -> StoreStats {
        let hot_bytes: u64 = read_poisonless(&self.partitions)
            .values()
            .map(|d| d.approx_bytes)
            .sum();
        StoreStats {
            tier: self.tier(),
            hot_hits: self.hot_hits.get(),
            faults: 0,
            evictions: 0,
            hot_bytes,
            spill_bytes: 0,
            fault_ns: HistogramSnapshot::default(),
        }
    }

    fn tier(&self) -> &'static str {
        "resident"
    }
}

// ------------------------------------------------------------------
// Layered
// ------------------------------------------------------------------

/// One hot entry of a [`Layered`] store: payload + wire frame, both
/// shared, charged at the frame's byte size.
struct LayeredEntry {
    data: Arc<PartitionData>,
    frame: Arc<Vec<u8>>,
}

struct LayeredHot {
    map: HashMap<PartitionId, LayeredEntry>,
    bytes: u64,
    /// Faults per partition since startup — the admission signal.
    freq: HashMap<PartitionId, u64>,
}

/// A byte-budgeted *partial* hot set over any cold store, admitted by
/// per-partition fetch frequency: a partition enters the hot set once
/// it has faulted [`Layered::ADMIT_AFTER`] times, and the
/// least-frequently-fetched entries are evicted first when the budget
/// overflows.  This is the PR 2 follow-up policy — replicas holding
/// only the partitions their nodes actually pull — expressed as a
/// store composition (the replica server applies the same policy to
/// raw frames; see `service/data.rs`).
pub struct Layered {
    hot: Mutex<LayeredHot>,
    budget: u64,
    cold: Arc<dyn PartitionStore>,
    hot_hits: Counter,
    faults: Counter,
    evictions: Counter,
    fault_ns: Histogram,
}

impl Layered {
    /// Faults before a partition is admitted to the hot set: the first
    /// fetch only records interest, the second proves it is hot.
    pub const ADMIT_AFTER: u64 = 2;

    /// A layered store holding at most `budget` hot bytes over `cold`.
    pub fn new(budget: u64, cold: Arc<dyn PartitionStore>) -> Layered {
        Layered {
            hot: Mutex::new(LayeredHot {
                map: HashMap::new(),
                bytes: 0,
                freq: HashMap::new(),
            }),
            budget,
            cold,
            hot_hits: Counter::new(),
            faults: Counter::new(),
            evictions: Counter::new(),
            fault_ns: Histogram::new(),
        }
    }

    /// Fault `id` from the cold tier, bump its frequency, and admit it
    /// to the hot set if it has earned residence.
    fn fault(
        &self,
        id: PartitionId,
    ) -> Result<(Arc<PartitionData>, Arc<Vec<u8>>), StoreError> {
        let t0 = Stopwatch::start();
        let data = self.cold.get(id)?;
        let frame = self.cold.encoded_frame(id)?;
        self.faults.inc();
        self.fault_ns.observe(t0.elapsed_ns());
        let mut hot = lock_poisonless(&self.hot);
        let freq = hot.freq.entry(id).or_insert(0);
        *freq += 1;
        if *freq >= Self::ADMIT_AFTER {
            self.admit(&mut hot, id, data.clone(), frame.clone());
        }
        Ok((data, frame))
    }

    /// Insert `id` hot, evicting least-frequently-fetched entries
    /// until the budget holds.  An entry larger than the whole budget
    /// is served but never admitted.
    fn admit(
        &self,
        hot: &mut LayeredHot,
        id: PartitionId,
        data: Arc<PartitionData>,
        frame: Arc<Vec<u8>>,
    ) {
        let incoming = frame.len() as u64;
        if incoming > self.budget || hot.map.contains_key(&id) {
            return;
        }
        while hot.bytes + incoming > self.budget {
            let coldest = hot
                .map
                .keys()
                .min_by_key(|p| {
                    (hot.freq.get(*p).copied().unwrap_or(0), p.0)
                })
                .copied();
            let Some(victim) = coldest else { break };
            if let Some(e) = hot.map.remove(&victim) {
                hot.bytes -= e.frame.len() as u64;
                self.evictions.inc();
            }
        }
        hot.bytes += incoming;
        hot.map.insert(id, LayeredEntry { data, frame });
    }

    /// Ids currently held hot (the partial set), ascending — what a
    /// partial replica would announce.
    pub fn hot_ids(&self) -> Vec<PartitionId> {
        let hot = lock_poisonless(&self.hot);
        let mut ids: Vec<PartitionId> =
            hot.map.keys().copied().collect();
        ids.sort_unstable_by_key(|p| p.0);
        ids
    }
}

impl PartitionStore for Layered {
    fn get(
        &self,
        id: PartitionId,
    ) -> Result<Arc<PartitionData>, StoreError> {
        if let Some(e) = lock_poisonless(&self.hot).map.get(&id) {
            self.hot_hits.inc();
            return Ok(e.data.clone());
        }
        self.fault(id).map(|(data, _)| data)
    }

    fn encoded_frame(
        &self,
        id: PartitionId,
    ) -> Result<Arc<Vec<u8>>, StoreError> {
        if let Some(e) = lock_poisonless(&self.hot).map.get(&id) {
            self.hot_hits.inc();
            return Ok(e.frame.clone());
        }
        self.fault(id).map(|(_, frame)| frame)
    }

    fn payload_bytes(&self, id: PartitionId) -> Option<u64> {
        self.cold.payload_bytes(id)
    }

    fn ids(&self) -> Vec<PartitionId> {
        self.cold.ids()
    }

    fn insert(&self, data: Arc<PartitionData>) -> Result<(), StoreError> {
        // a replaced payload must not be served stale from the hot set
        {
            let mut hot = lock_poisonless(&self.hot);
            if let Some(e) = hot.map.remove(&data.id) {
                hot.bytes -= e.frame.len() as u64;
            }
        }
        self.cold.insert(data)
    }

    fn stats(&self) -> StoreStats {
        let hot_bytes = lock_poisonless(&self.hot).bytes;
        StoreStats {
            tier: self.tier(),
            hot_hits: self.hot_hits.get(),
            faults: self.faults.get(),
            evictions: self.evictions.get(),
            hot_bytes,
            spill_bytes: self.cold.stats().spill_bytes,
            fault_ns: self.fault_ns.snapshot(),
        }
    }

    fn tier(&self) -> &'static str {
        "layered"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::model::EntityId;
    use crate::partition::partition_size_based;
    use crate::store::DataService;

    fn resident_with(
        entities: usize,
        max: usize,
    ) -> (Arc<Resident>, Vec<PartitionId>) {
        let data = GeneratorConfig::tiny()
            .with_entities(entities)
            .generate();
        let ids: Vec<EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let parts = partition_size_based(&ids, max);
        let store = DataService::build(&data.dataset, &parts);
        let resident = Arc::new(Resident::new());
        let mut pids = Vec::new();
        for p in parts.iter() {
            resident
                .insert(store.fetch(p.id).expect("built partition"))
                .unwrap();
            pids.push(p.id);
        }
        pids.sort_unstable_by_key(|p| p.0);
        (resident, pids)
    }

    #[test]
    fn resident_serves_and_reports_unknown() {
        let (store, pids) = resident_with(120, 40);
        assert_eq!(store.ids(), pids);
        for &id in &pids {
            let d = store.get(id).unwrap();
            assert_eq!(d.id, id);
            assert_eq!(
                store.payload_bytes(id),
                Some(d.approx_bytes)
            );
            // the cached frame is exactly the encoder's output
            let frame = store.encoded_frame(id).unwrap();
            assert_eq!(*frame, encode_partition_message(&d));
            // second serve returns the same Arc (cached, no re-encode)
            assert!(Arc::ptr_eq(
                &frame,
                &store.encoded_frame(id).unwrap()
            ));
        }
        let missing = PartitionId(9999);
        assert_eq!(
            store.get(missing).unwrap_err(),
            StoreError::Unknown(missing)
        );
        assert!(store.try_get(missing).is_none());
        assert_eq!(store.payload_bytes(missing), None);
        let s = store.stats();
        assert_eq!(s.tier, "resident");
        assert_eq!(s.faults, 0);
        assert!(s.hot_hits > 0);
        assert!(s.hot_bytes > 0);
    }

    #[test]
    fn resident_insert_invalidates_cached_frame() {
        let (store, pids) = resident_with(80, 40);
        let id = pids[0];
        let before = store.encoded_frame(id).unwrap();
        // replace the payload with a truncated copy of itself
        let d = store.get(id).unwrap();
        store.insert(Arc::new(d.slice(0, 1))).unwrap();
        let after = store.encoded_frame(id).unwrap();
        assert_ne!(*before, *after, "stale frame served after replace");
        assert_eq!(
            *after,
            encode_partition_message(&store.get(id).unwrap())
        );
    }

    /// PR 8 regression, re-homed with the backend: a panic while
    /// holding the partition map must not wedge later reads.
    #[test]
    fn resident_poisoned_lock_recovers() {
        let (store, pids) = resident_with(80, 40);
        let s = store.clone();
        assert!(std::thread::spawn(move || {
            let _g = s.partitions.write().unwrap();
            panic!("handler panics while holding the partition map");
        })
        .join()
        .is_err());
        let d = store.get(pids[0]).expect("read after poison");
        assert_eq!(d.id, pids[0]);
        assert_eq!(store.ids().len(), pids.len());
    }

    #[test]
    fn layered_admits_by_frequency_and_holds_budget() {
        let (cold, pids) = resident_with(200, 20);
        assert!(pids.len() >= 4, "need several partitions");
        let frame_len =
            cold.encoded_frame(pids[0]).unwrap().len() as u64;
        // room for roughly two average frames
        let layered = Layered::new(frame_len * 2, cold.clone());

        // first fetch: fault, not yet admitted
        layered.get(pids[0]).unwrap();
        assert!(layered.hot_ids().is_empty(), "admitted on 1st fault");
        // second fetch: fault again (still cold), now admitted
        layered.get(pids[0]).unwrap();
        assert_eq!(layered.hot_ids(), vec![pids[0]]);
        // third fetch is a hot hit
        let before = layered.stats().hot_hits;
        layered.get(pids[0]).unwrap();
        assert_eq!(layered.stats().hot_hits, before + 1);

        // heat every partition; the hot set must stay under budget
        for _ in 0..2 {
            for &id in &pids {
                let d = layered.get(id).unwrap();
                assert_eq!(d.id, id);
            }
        }
        let s = layered.stats();
        assert!(
            s.hot_bytes <= frame_len * 2,
            "hot {} over budget {}",
            s.hot_bytes,
            frame_len * 2
        );
        assert!(
            layered.hot_ids().len() < pids.len(),
            "a partial set must not hold everything"
        );
        assert!(s.evictions > 0, "budget pressure must evict");
        assert!(s.faults > 0);
        assert_eq!(s.fault_ns.count, s.faults);

        // served bytes are identical to the cold tier, hot or not
        for &id in &pids {
            assert_eq!(
                *layered.encoded_frame(id).unwrap(),
                *cold.encoded_frame(id).unwrap()
            );
        }
    }

    #[test]
    fn layered_insert_drops_stale_hot_entry() {
        let (cold, pids) = resident_with(80, 40);
        let layered = Layered::new(u64::MAX, cold.clone());
        let id = pids[0];
        layered.get(id).unwrap();
        layered.get(id).unwrap(); // admitted now
        assert_eq!(layered.hot_ids(), vec![id]);
        let replacement = layered.get(id).unwrap().slice(0, 1);
        layered.insert(Arc::new(replacement)).unwrap();
        // the hot copy is gone; the next get serves the new payload
        let d = layered.get(id).unwrap();
        assert_eq!(d.len(), 1);
    }

    #[test]
    fn store_stats_snapshot_is_scrapable_and_merges() {
        let (store, pids) = resident_with(80, 40);
        store.get(pids[0]).unwrap();
        let snap = store.stats().to_snapshot();
        assert_eq!(snap.label("store.tier"), Some("resident"));
        assert_eq!(snap.counter("store.hot_hits"), Some(1));
        assert_eq!(snap.counter("store.faults"), Some(0));
        assert!(snap.gauge("store.hot_bytes").unwrap() > 0);
        assert!(snap.histogram("store.fault_ns").is_some());
        // merging into a registry snapshot keeps both namespaces
        let reg = crate::obs::Registry::new();
        reg.counter("fetches_served").add(7);
        let merged = reg.snapshot().merge(&snap);
        assert_eq!(merged.counter("fetches_served"), Some(7));
        assert_eq!(merged.counter("store.hot_hits"), Some(1));
    }
}
