//! The data service (paper §4): central store for input partitions.
//!
//! Holds, per partition, the precomputed per-entity match features (and
//! lazily, the padded feature matrices for the accelerated PJRT path).
//! Match services fetch partitions from here; every fetch is accounted so
//! the engines can charge network cost and report communication overhead.
//!
//! Since PR 9 the payloads themselves live behind the object-safe
//! [`PartitionStore`] trait ([`tier`]): [`Resident`] keeps everything in
//! RAM (the historical behavior), [`SpillStore`] keeps a byte-budgeted
//! hot set backed by checksummed spill files ([`spill`]), and
//! [`Layered`] composes a frequency-driven partial hot set over any
//! cold store.  [`DataService`] is the accounting facade over whichever
//! backend was chosen: it owns the *logical* fetch statistics (traffic,
//! fetch log) the paper's communication-overhead numbers come from,
//! while the backend owns the *physical* ones (`store.*` metrics).

pub mod spill;
pub mod tier;

pub use spill::SpillStore;
pub use tier::{Layered, PartitionStore, Resident, StoreError, StoreStats};

use crate::features::{EntityFeatures, FeatureMatrix};
use crate::model::{Dataset, EntityId};
use crate::net::TrafficStats;
use crate::partition::{PartitionId, PartitionSet};
use crate::util::lock_poisonless;
use std::sync::{Arc, Mutex};

/// Operator-facing choice of the primary's store backend (`--store`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum StoreKind {
    /// Every payload resident in RAM (the historical behavior).
    #[default]
    Resident,
    /// Out-of-core: a byte-budgeted RAM hot set over checksummed
    /// per-partition spill files ([`SpillStore`]).
    Spill {
        /// Hot-set byte budget (`--store-budget`).
        budget: u64,
        /// Spill directory (`--spill-dir`); `None` = a fresh temp
        /// directory, removed when the store drops.
        dir: Option<std::path::PathBuf>,
    },
}

impl StoreKind {
    /// Open an empty backend of this kind.
    pub fn open(&self) -> std::io::Result<Arc<dyn PartitionStore>> {
        Ok(match self {
            StoreKind::Resident => Arc::new(Resident::new()),
            StoreKind::Spill { budget, dir } => {
                Arc::new(SpillStore::new(*budget, dir.clone())?)
            }
        })
    }
}

/// The transferable payload of one partition: entity ids + features.
#[derive(Debug)]
pub struct PartitionData {
    pub id: PartitionId,
    pub entities: Vec<EntityId>,
    pub features: Vec<EntityFeatures>,
    /// Serialized size estimate (bytes) for the network cost model.
    pub approx_bytes: u64,
}

impl PartitionData {
    pub fn len(&self) -> usize {
        self.entities.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entities.is_empty()
    }

    /// The sub-range `[start, end)` of this payload as an owned
    /// partition — what a match node executes for a runtime-split
    /// sub-task ([`crate::partition::TaskSpan`]): the full partition
    /// is fetched (and cached) once, then sliced down to the assigned
    /// entity range.  Bounds are clamped to the payload, so a
    /// malformed span yields an empty slice instead of a panic;
    /// `approx_bytes` is scaled by the kept fraction.
    pub fn slice(&self, start: usize, end: usize) -> PartitionData {
        let end = end.min(self.entities.len());
        let start = start.min(end);
        let approx_bytes = if self.entities.is_empty() {
            0
        } else {
            self.approx_bytes * (end - start) as u64
                / self.entities.len() as u64
        };
        PartitionData {
            id: self.id,
            entities: self.entities[start..end].to_vec(),
            features: self.features[start..end].to_vec(),
            approx_bytes,
        }
    }

    /// Assemble the padded title/description feature matrices for the
    /// accelerated path (`f32[capacity, dim]`, zero-padded).
    pub fn feature_matrices(&self, capacity: usize, dim: usize) -> (FeatureMatrix, FeatureMatrix) {
        let titles: Vec<&crate::features::QGramSet> =
            self.features.iter().map(|f| &f.title_grams).collect();
        let descs: Vec<&crate::features::QGramSet> =
            self.features.iter().map(|f| &f.desc_grams).collect();
        (
            FeatureMatrix::from_qgrams(&titles, capacity, dim),
            FeatureMatrix::from_qgrams(&descs, capacity, dim),
        )
    }

    /// Materialize the payload of one partition from the dataset:
    /// per-entity features plus the cost-model size estimate.
    pub fn materialize(
        dataset: &Dataset,
        id: PartitionId,
        entities: &[EntityId],
    ) -> PartitionData {
        let features: Vec<EntityFeatures> = entities
            .iter()
            .map(|e| {
                EntityFeatures::of(&dataset.entities[e.0 as usize], dataset)
            })
            .collect();
        let approx_bytes = features
            .iter()
            .map(|f| f.approx_bytes() as u64)
            .sum::<u64>()
            + 8 * entities.len() as u64;
        PartitionData {
            id,
            entities: entities.to_vec(),
            features,
            approx_bytes,
        }
    }
}

/// Central data service.  Thread-safe; fetches return `Arc`s so cached
/// copies are shared, not cloned.  Since protocol v7 the partition map
/// is runtime-growable ([`DataService::extend`]): a resident workflow
/// service inserts the partitions of every admitted tenant plan into
/// the live store, so match nodes can fetch them like seed partitions
/// (and the anti-entropy sync streams propagate them to replicas).
///
/// The payloads live in an exchangeable [`PartitionStore`] backend;
/// this facade adds the logical accounting on top.  Partitions are
/// materialized one at a time and handed to the backend immediately,
/// so with a [`SpillStore`] backend peak memory is bounded by the
/// store budget plus one partition, not the catalog.
pub struct DataService {
    store: Arc<dyn PartitionStore>,
    pub traffic: TrafficStats,
    fetch_log: Mutex<Vec<PartitionId>>,
}

impl DataService {
    /// Build a fully [`Resident`] store: materialize each partition's
    /// payload in RAM — the historical (pre-tier) behavior.
    pub fn build(dataset: &Dataset, parts: &PartitionSet) -> DataService {
        Self::build_with(dataset, parts, Arc::new(Resident::new()))
            .expect("resident insert cannot fail")
    }

    /// Build on an explicit backend.  Partitions are materialized and
    /// inserted one by one (a spill backend persists each before the
    /// next is computed).  Fails only if the backend does — e.g. a
    /// spill directory that cannot be written.
    pub fn build_with(
        dataset: &Dataset,
        parts: &PartitionSet,
        store: Arc<dyn PartitionStore>,
    ) -> Result<DataService, StoreError> {
        let svc = Self::with_store(store);
        for p in parts.iter() {
            svc.store.insert(Arc::new(PartitionData::materialize(
                dataset, p.id, &p.entities,
            )))?;
        }
        Ok(svc)
    }

    /// An empty facade over `store` (which may already hold payloads —
    /// e.g. a replica's partial hot set).
    pub fn with_store(store: Arc<dyn PartitionStore>) -> DataService {
        DataService {
            store,
            traffic: TrafficStats::new(),
            fetch_log: Mutex::new(Vec::new()),
        }
    }

    /// The backend this facade accounts for.
    pub fn store(&self) -> &Arc<dyn PartitionStore> {
        &self.store
    }

    /// Physical storage counters of the backend (`store.*` metrics).
    pub fn store_stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Which tier backs this service (`"resident"`, `"spill"`, …).
    pub fn tier(&self) -> &'static str {
        self.store.tier()
    }

    /// Insert the partitions of an admitted tenant plan (protocol v7),
    /// each renumbered to `PartitionId(original + id_offset)` so
    /// tenants can never collide with the seed workflow or each other.
    /// Features are recomputed from `dataset` exactly like
    /// [`DataService::build`] does — the submitted plan references
    /// entities of the *host's* dataset (fingerprint-checked at
    /// admission).  Returns the renumbered ids, ascending; a backend
    /// failure (e.g. spill disk full) is a typed error the admission
    /// path turns into a plan rejection instead of a server panic.
    pub fn extend(
        &self,
        dataset: &Dataset,
        parts: &PartitionSet,
        id_offset: u32,
    ) -> Result<Vec<PartitionId>, StoreError> {
        let mut added = Vec::new();
        for p in parts.iter() {
            let id = PartitionId(p.id.0 + id_offset);
            self.store.insert(Arc::new(PartitionData::materialize(
                dataset,
                id,
                &p.entities,
            )))?;
            added.push(id);
        }
        added.sort_unstable_by_key(|p| p.0);
        Ok(added)
    }

    /// The highest partition id held (`None` for an empty store) — the
    /// renumbering base for [`DataService::extend`].
    pub fn max_partition_id(&self) -> Option<u32> {
        self.store.ids().last().map(|p| p.0)
    }

    /// Fetch a partition (counts as one data-service access — a *cache
    /// miss* on the match-service side).  An unknown id is a typed
    /// [`StoreError`], not a panic — the TCP fetch arm and replica
    /// sync turn it into a protocol error frame.  Accounting is only
    /// charged on success.
    pub fn fetch(
        &self,
        id: PartitionId,
    ) -> Result<Arc<PartitionData>, StoreError> {
        let data = self.store.get(id)?;
        self.traffic.record(data.approx_bytes);
        lock_poisonless(&self.fetch_log).push(id);
        Ok(data)
    }

    /// Fetch the encoded wire frame of a partition, with the same
    /// logical accounting as [`DataService::fetch`] — what the TCP data
    /// service ships (zero-copy, shared across sessions).  The charge
    /// is the payload's cost-model size, identical across backends.
    pub fn fetch_frame(
        &self,
        id: PartitionId,
    ) -> Result<Arc<Vec<u8>>, StoreError> {
        let bytes =
            self.store.payload_bytes(id).ok_or(StoreError::Unknown(id))?;
        let frame = self.store.encoded_frame(id)?;
        self.traffic.record(bytes);
        lock_poisonless(&self.fetch_log).push(id);
        Ok(frame)
    }

    /// [`DataService::fetch`] flattened to an `Option` for callers that
    /// only branch on presence.
    pub fn try_fetch(&self, id: PartitionId) -> Option<Arc<PartitionData>> {
        self.fetch(id).ok()
    }

    /// Look a partition up **without accounting** — used by data-plane
    /// replication, which pushes every partition to the replicas once
    /// and must not inflate the logical fetch statistics the paper's
    /// cache-effectiveness numbers are computed from.
    pub fn peek(&self, id: PartitionId) -> Option<Arc<PartitionData>> {
        self.store.try_get(id)
    }

    /// [`DataService::peek`] for the encoded frame: no logical
    /// accounting — replica sync streams are physical traffic.
    pub fn peek_frame(&self, id: PartitionId) -> Option<Arc<Vec<u8>>> {
        self.store.encoded_frame(id).ok()
    }

    /// All partition ids held by this store, ascending.  Replica
    /// announcements and sync streams enumerate partitions with this.
    pub fn partition_ids(&self) -> Vec<PartitionId> {
        self.store.ids()
    }

    /// Size of a partition payload without fetching (the simulator
    /// charges transfer time from this); `None` for unknown ids.
    pub fn payload_bytes(&self, id: PartitionId) -> Option<u64> {
        self.store.payload_bytes(id)
    }

    pub fn n_partitions(&self) -> usize {
        self.store.ids().len()
    }

    pub fn fetches(&self) -> usize {
        lock_poisonless(&self.fetch_log).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::features::DEFAULT_DIM;
    use crate::partition::partition_size_based;

    fn setup() -> (crate::datagen::GeneratedData, PartitionSet) {
        let data = GeneratorConfig::tiny().generate();
        let ids: Vec<EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let ps = partition_size_based(&ids, 100);
        (data, ps)
    }

    #[test]
    fn build_covers_all_partitions() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        assert_eq!(store.n_partitions(), ps.len());
        assert_eq!(store.tier(), "resident");
        for p in ps.iter() {
            let d = store.fetch(p.id).unwrap();
            assert_eq!(d.len(), p.len());
            assert_eq!(d.entities, p.entities);
            assert_eq!(d.features.len(), p.len());
        }
    }

    #[test]
    fn fetch_accounting() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        let id = ps.iter().next().unwrap().id;
        let before = store.traffic.total_bytes();
        store.fetch(id).unwrap();
        store.fetch(id).unwrap();
        assert_eq!(store.fetches(), 2);
        assert_eq!(
            store.traffic.total_bytes() - before,
            2 * store.payload_bytes(id).unwrap()
        );
    }

    #[test]
    fn fetch_frame_accounts_like_fetch() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        let id = ps.iter().next().unwrap().id;
        let before = store.traffic.total_bytes();
        let frame = store.fetch_frame(id).unwrap();
        assert!(!frame.is_empty());
        assert_eq!(store.fetches(), 1);
        assert_eq!(
            store.traffic.total_bytes() - before,
            store.payload_bytes(id).unwrap()
        );
        // peek_frame serves the same shared bytes without accounting
        let peeked = store.peek_frame(id).unwrap();
        assert!(Arc::ptr_eq(&frame, &peeked));
        assert_eq!(store.fetches(), 1);
    }

    #[test]
    fn payload_bytes_positive_and_scales() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        let mut sizes: Vec<(usize, u64)> = ps
            .iter()
            .map(|p| (p.len(), store.payload_bytes(p.id).unwrap()))
            .collect();
        sizes.sort();
        assert!(sizes[0].1 > 0);
        // payload grows with entity count (same generator distribution)
        assert!(sizes[sizes.len() - 1].1 >= sizes[0].1);
    }

    #[test]
    fn feature_matrices_shapes() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        let p = ps.iter().next().unwrap();
        let d = store.fetch(p.id).unwrap();
        let (t, desc) = d.feature_matrices(128, DEFAULT_DIM);
        assert_eq!(t.capacity, 128);
        assert_eq!(t.rows, p.len());
        assert_eq!(t.dim, DEFAULT_DIM);
        assert_eq!(desc.data.len(), 128 * DEFAULT_DIM);
    }

    #[test]
    fn slice_selects_range_and_clamps_bounds() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        let p = ps.iter().next().unwrap();
        let d = store.fetch(p.id).unwrap();
        let s = d.slice(10, 40);
        assert_eq!(s.len(), 30);
        assert_eq!(s.entities, d.entities[10..40]);
        assert_eq!(s.features.len(), 30);
        assert_eq!(s.id, d.id);
        assert!(s.approx_bytes > 0 && s.approx_bytes < d.approx_bytes);
        // malformed bounds clamp to empty instead of panicking
        assert!(d.slice(500, 900).is_empty());
        assert!(d.slice(40, 10).is_empty());
        assert_eq!(d.slice(0, d.len()).entities, d.entities);
    }

    #[test]
    fn extend_inserts_renumbered_tenant_partitions() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        let before = store.n_partitions();
        let offset = store.max_partition_id().unwrap() + 1;
        let added = store.extend(&data.dataset, &ps, offset).unwrap();
        assert_eq!(added.len(), ps.len());
        assert_eq!(store.n_partitions(), before + ps.len());
        // renumbered payloads are byte-equal to the originals except
        // for the id
        for p in ps.iter() {
            let orig = store.fetch(p.id).unwrap();
            let ten =
                store.fetch(PartitionId(p.id.0 + offset)).unwrap();
            assert_eq!(ten.id.0, p.id.0 + offset);
            assert_eq!(ten.entities, orig.entities);
            assert_eq!(ten.approx_bytes, orig.approx_bytes);
        }
        // the original namespace is untouched
        assert_eq!(
            store.max_partition_id().unwrap(),
            offset + ps.iter().map(|p| p.id.0).max().unwrap()
        );
    }

    /// PR 9 satellite: an unknown id is a typed miss on every path —
    /// no accounting charged, no panic anywhere.
    #[test]
    fn unknown_partition_is_a_typed_miss() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        let bogus = PartitionId(9999);
        let before = store.traffic.total_bytes();
        assert_eq!(
            store.fetch(bogus).unwrap_err(),
            StoreError::Unknown(bogus)
        );
        assert_eq!(
            store.fetch_frame(bogus),
            Err(StoreError::Unknown(bogus))
        );
        assert!(store.try_fetch(bogus).is_none());
        assert!(store.peek(bogus).is_none());
        assert!(store.payload_bytes(bogus).is_none());
        assert_eq!(store.traffic.total_bytes(), before);
        assert_eq!(store.fetches(), 0);
    }

    /// PR 8 satellite regression: a panic while holding a store lock
    /// (e.g. a frame handler dying mid-request) must not wedge every
    /// other connection with `PoisonError` unwraps.  The partition-map
    /// half of this regression now lives with the backend
    /// (`tier::tests::resident_poisoned_lock_recovers`); the facade
    /// owns the fetch log.
    #[test]
    fn poisoned_locks_recover_instead_of_wedging() {
        let (data, ps) = setup();
        let store = Arc::new(DataService::build(&data.dataset, &ps));
        let id = ps.iter().next().unwrap().id;

        let s = store.clone();
        assert!(std::thread::spawn(move || {
            let _g = s.fetch_log.lock().unwrap();
            panic!("handler panics while holding the fetch log");
        })
        .join()
        .is_err());

        // The lock is now poisoned; the service must still serve.
        let d = store.try_fetch(id).expect("fetch after poison");
        assert_eq!(d.id, id);
        assert_eq!(store.fetches(), 1);
        assert_eq!(store.n_partitions(), ps.len());
        assert!(store.max_partition_id().is_some());
    }
}
