//! The data service (paper §4): central store for input partitions.
//!
//! Holds, per partition, the precomputed per-entity match features (and
//! lazily, the padded feature matrices for the accelerated PJRT path).
//! Match services fetch partitions from here; every fetch is accounted so
//! the engines can charge network cost and report communication overhead.

use crate::features::{EntityFeatures, FeatureMatrix};
use crate::model::{Dataset, EntityId};
use crate::net::TrafficStats;
use crate::partition::{PartitionId, PartitionSet};
use crate::util::{lock_poisonless, read_poisonless, write_poisonless};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

/// The transferable payload of one partition: entity ids + features.
#[derive(Debug)]
pub struct PartitionData {
    pub id: PartitionId,
    pub entities: Vec<EntityId>,
    pub features: Vec<EntityFeatures>,
    /// Serialized size estimate (bytes) for the network cost model.
    pub approx_bytes: u64,
}

impl PartitionData {
    pub fn len(&self) -> usize {
        self.entities.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entities.is_empty()
    }

    /// The sub-range `[start, end)` of this payload as an owned
    /// partition — what a match node executes for a runtime-split
    /// sub-task ([`crate::partition::TaskSpan`]): the full partition
    /// is fetched (and cached) once, then sliced down to the assigned
    /// entity range.  Bounds are clamped to the payload, so a
    /// malformed span yields an empty slice instead of a panic;
    /// `approx_bytes` is scaled by the kept fraction.
    pub fn slice(&self, start: usize, end: usize) -> PartitionData {
        let end = end.min(self.entities.len());
        let start = start.min(end);
        let approx_bytes = if self.entities.is_empty() {
            0
        } else {
            self.approx_bytes * (end - start) as u64
                / self.entities.len() as u64
        };
        PartitionData {
            id: self.id,
            entities: self.entities[start..end].to_vec(),
            features: self.features[start..end].to_vec(),
            approx_bytes,
        }
    }

    /// Assemble the padded title/description feature matrices for the
    /// accelerated path (`f32[capacity, dim]`, zero-padded).
    pub fn feature_matrices(&self, capacity: usize, dim: usize) -> (FeatureMatrix, FeatureMatrix) {
        let titles: Vec<&crate::features::QGramSet> =
            self.features.iter().map(|f| &f.title_grams).collect();
        let descs: Vec<&crate::features::QGramSet> =
            self.features.iter().map(|f| &f.desc_grams).collect();
        (
            FeatureMatrix::from_qgrams(&titles, capacity, dim),
            FeatureMatrix::from_qgrams(&descs, capacity, dim),
        )
    }
}

/// Central data service.  Thread-safe; fetches return `Arc`s so cached
/// copies are shared, not cloned.  Since protocol v7 the partition map
/// is runtime-growable ([`DataService::extend`]): a resident workflow
/// service inserts the partitions of every admitted tenant plan into
/// the live store, so match nodes can fetch them like seed partitions
/// (and the anti-entropy sync streams propagate them to replicas).
pub struct DataService {
    partitions: RwLock<HashMap<PartitionId, Arc<PartitionData>>>,
    pub traffic: TrafficStats,
    fetch_log: Mutex<Vec<PartitionId>>,
}

impl DataService {
    /// Build the store: precompute features for every entity once, then
    /// materialize each partition's payload.
    pub fn build(dataset: &Dataset, parts: &PartitionSet) -> DataService {
        let all_features: Vec<EntityFeatures> = dataset
            .entities
            .iter()
            .map(|e| EntityFeatures::of(e, dataset))
            .collect();
        let mut partitions = HashMap::new();
        for p in parts.iter() {
            let features: Vec<EntityFeatures> = p
                .entities
                .iter()
                .map(|id| all_features[id.0 as usize].clone())
                .collect();
            let approx_bytes = features
                .iter()
                .map(|f| f.approx_bytes() as u64)
                .sum::<u64>()
                + 8 * p.entities.len() as u64;
            partitions.insert(
                p.id,
                Arc::new(PartitionData {
                    id: p.id,
                    entities: p.entities.clone(),
                    features,
                    approx_bytes,
                }),
            );
        }
        DataService {
            partitions: RwLock::new(partitions),
            traffic: TrafficStats::new(),
            fetch_log: Mutex::new(Vec::new()),
        }
    }

    /// Insert the partitions of an admitted tenant plan (protocol v7),
    /// each renumbered to `PartitionId(original + id_offset)` so
    /// tenants can never collide with the seed workflow or each other.
    /// Features are recomputed from `dataset` exactly like
    /// [`DataService::build`] does — the submitted plan references
    /// entities of the *host's* dataset (fingerprint-checked at
    /// admission).  Returns the renumbered ids, ascending.
    pub fn extend(
        &self,
        dataset: &Dataset,
        parts: &PartitionSet,
        id_offset: u32,
    ) -> Vec<PartitionId> {
        let mut added = Vec::new();
        let mut map = write_poisonless(&self.partitions);
        for p in parts.iter() {
            let features: Vec<EntityFeatures> = p
                .entities
                .iter()
                .map(|id| {
                    EntityFeatures::of(
                        &dataset.entities[id.0 as usize],
                        dataset,
                    )
                })
                .collect();
            let approx_bytes = features
                .iter()
                .map(|f| f.approx_bytes() as u64)
                .sum::<u64>()
                + 8 * p.entities.len() as u64;
            let id = PartitionId(p.id.0 + id_offset);
            map.insert(
                id,
                Arc::new(PartitionData {
                    id,
                    entities: p.entities.clone(),
                    features,
                    approx_bytes,
                }),
            );
            added.push(id);
        }
        added.sort_unstable_by_key(|p| p.0);
        added
    }

    /// The highest partition id held (`None` for an empty store) — the
    /// renumbering base for [`DataService::extend`].
    pub fn max_partition_id(&self) -> Option<u32> {
        read_poisonless(&self.partitions).keys().map(|p| p.0).max()
    }

    /// Fetch a partition (counts as one data-service access — a *cache
    /// miss* on the match-service side).
    pub fn fetch(&self, id: PartitionId) -> Arc<PartitionData> {
        self.try_fetch(id)
            .unwrap_or_else(|| panic!("unknown partition {id}"))
    }

    /// Fetch without panicking on unknown ids — the TCP data service
    /// answers malformed remote requests with an error message instead
    /// of dying (see [`crate::service::DataServiceServer`]).  Accounting
    /// is only charged on success.
    pub fn try_fetch(&self, id: PartitionId) -> Option<Arc<PartitionData>> {
        let data = read_poisonless(&self.partitions).get(&id)?.clone();
        self.traffic.record(data.approx_bytes);
        lock_poisonless(&self.fetch_log).push(id);
        Some(data)
    }

    /// Look a partition up **without accounting** — used by data-plane
    /// replication, which pushes every partition to the replicas once
    /// and must not inflate the logical fetch statistics the paper's
    /// cache-effectiveness numbers are computed from.
    pub fn peek(&self, id: PartitionId) -> Option<Arc<PartitionData>> {
        read_poisonless(&self.partitions).get(&id).cloned()
    }

    /// All partition ids held by this store, ascending.  Replica
    /// announcements and sync streams enumerate partitions with this.
    pub fn partition_ids(&self) -> Vec<PartitionId> {
        let mut ids: Vec<PartitionId> =
            read_poisonless(&self.partitions).keys().copied().collect();
        ids.sort_unstable_by_key(|p| p.0);
        ids
    }

    /// Size of a partition payload without fetching (the simulator charges
    /// transfer time from this).
    pub fn payload_bytes(&self, id: PartitionId) -> u64 {
        read_poisonless(&self.partitions)
            .get(&id)
            .unwrap_or_else(|| panic!("unknown partition {id}"))
            .approx_bytes
    }

    pub fn n_partitions(&self) -> usize {
        read_poisonless(&self.partitions).len()
    }

    pub fn fetches(&self) -> usize {
        lock_poisonless(&self.fetch_log).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::features::DEFAULT_DIM;
    use crate::partition::partition_size_based;

    fn setup() -> (crate::datagen::GeneratedData, PartitionSet) {
        let data = GeneratorConfig::tiny().generate();
        let ids: Vec<EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let ps = partition_size_based(&ids, 100);
        (data, ps)
    }

    #[test]
    fn build_covers_all_partitions() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        assert_eq!(store.n_partitions(), ps.len());
        for p in ps.iter() {
            let d = store.fetch(p.id);
            assert_eq!(d.len(), p.len());
            assert_eq!(d.entities, p.entities);
            assert_eq!(d.features.len(), p.len());
        }
    }

    #[test]
    fn fetch_accounting() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        let id = ps.iter().next().unwrap().id;
        let before = store.traffic.total_bytes();
        store.fetch(id);
        store.fetch(id);
        assert_eq!(store.fetches(), 2);
        assert_eq!(
            store.traffic.total_bytes() - before,
            2 * store.payload_bytes(id)
        );
    }

    #[test]
    fn payload_bytes_positive_and_scales() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        let mut sizes: Vec<(usize, u64)> = ps
            .iter()
            .map(|p| (p.len(), store.payload_bytes(p.id)))
            .collect();
        sizes.sort();
        assert!(sizes[0].1 > 0);
        // payload grows with entity count (same generator distribution)
        assert!(sizes[sizes.len() - 1].1 >= sizes[0].1);
    }

    #[test]
    fn feature_matrices_shapes() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        let p = ps.iter().next().unwrap();
        let d = store.fetch(p.id);
        let (t, desc) = d.feature_matrices(128, DEFAULT_DIM);
        assert_eq!(t.capacity, 128);
        assert_eq!(t.rows, p.len());
        assert_eq!(t.dim, DEFAULT_DIM);
        assert_eq!(desc.data.len(), 128 * DEFAULT_DIM);
    }

    #[test]
    fn slice_selects_range_and_clamps_bounds() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        let p = ps.iter().next().unwrap();
        let d = store.fetch(p.id);
        let s = d.slice(10, 40);
        assert_eq!(s.len(), 30);
        assert_eq!(s.entities, d.entities[10..40]);
        assert_eq!(s.features.len(), 30);
        assert_eq!(s.id, d.id);
        assert!(s.approx_bytes > 0 && s.approx_bytes < d.approx_bytes);
        // malformed bounds clamp to empty instead of panicking
        assert!(d.slice(500, 900).is_empty());
        assert!(d.slice(40, 10).is_empty());
        assert_eq!(d.slice(0, d.len()).entities, d.entities);
    }

    #[test]
    fn extend_inserts_renumbered_tenant_partitions() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        let before = store.n_partitions();
        let offset = store.max_partition_id().unwrap() + 1;
        let added = store.extend(&data.dataset, &ps, offset);
        assert_eq!(added.len(), ps.len());
        assert_eq!(store.n_partitions(), before + ps.len());
        // renumbered payloads are byte-equal to the originals except
        // for the id
        for p in ps.iter() {
            let orig = store.fetch(p.id);
            let ten = store.fetch(PartitionId(p.id.0 + offset));
            assert_eq!(ten.id.0, p.id.0 + offset);
            assert_eq!(ten.entities, orig.entities);
            assert_eq!(ten.approx_bytes, orig.approx_bytes);
        }
        // the original namespace is untouched
        assert_eq!(
            store.max_partition_id().unwrap(),
            offset + ps.iter().map(|p| p.id.0).max().unwrap()
        );
    }

    #[test]
    #[should_panic]
    fn unknown_partition_panics() {
        let (data, ps) = setup();
        let store = DataService::build(&data.dataset, &ps);
        store.fetch(PartitionId(9999));
    }

    /// PR 8 satellite regression: a panic while holding a store lock
    /// (e.g. a frame handler dying mid-request) must not wedge every
    /// other connection with `PoisonError` unwraps.
    #[test]
    fn poisoned_locks_recover_instead_of_wedging() {
        let (data, ps) = setup();
        let store = Arc::new(DataService::build(&data.dataset, &ps));
        let id = ps.iter().next().unwrap().id;

        let s = store.clone();
        assert!(std::thread::spawn(move || {
            let _g = s.partitions.write().unwrap();
            panic!("handler panics while holding the partition map");
        })
        .join()
        .is_err());
        let s = store.clone();
        assert!(std::thread::spawn(move || {
            let _g = s.fetch_log.lock().unwrap();
            panic!("handler panics while holding the fetch log");
        })
        .join()
        .is_err());

        // Both locks are now poisoned; the service must still serve.
        let d = store.try_fetch(id).expect("fetch after poison");
        assert_eq!(d.id, id);
        assert_eq!(store.fetches(), 1);
        assert_eq!(store.n_partitions(), ps.len());
        assert!(store.max_partition_id().is_some());
    }
}
