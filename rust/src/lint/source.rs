//! The lint scanner: a masking pass over Rust source text.
//!
//! `pem-lint` never parses Rust — it *masks*.  A byte-level pass turns
//! everything that is not executable non-test code into spaces while
//! preserving newlines (so byte offsets still map to line numbers):
//!
//! 1. comments (`//…`, nested `/*…*/`) → spaces;
//! 2. string literal *contents* → spaces, keeping the quotes and
//!    remembering the original text (the L4 pass needs the metric-name
//!    literals back); raw strings (`r"…"`, `r#"…"#`, `br"…"`) and char
//!    literals masked whole;
//! 3. every `#[cfg(test)]`-gated item (attribute through its matching
//!    `}` or `;`) → spaces, so test-only code is exempt by
//!    construction.
//!
//! The masked text is then *condensed*: all whitespace removed, with a
//! position map back to raw byte offsets.  Pattern checks search the
//! condensed stream, which makes them immune to formatting — a
//! `.lock()\n    .unwrap()` chain split across lines matches
//! `.lock().unwrap()` all the same.
//!
//! A Python replica of this scanner lives at
//! `scripts/lint_replica.py`; keep the two in step.

use std::collections::HashMap;

/// A scanned source file, ready for pattern checks.
pub struct ScannedFile {
    /// Path relative to the scanned source root, `/`-separated
    /// (e.g. `obs/clock.rs`).
    pub rel: String,
    /// Byte offsets of `\n` in the raw text (line mapping).
    newlines: Vec<usize>,
    /// The condensed masked stream (no whitespace).
    pub cond: String,
    /// `cond` byte index → raw byte offset.
    pos: Vec<usize>,
    /// Raw-offset-of-opening-quote → original literal text, for
    /// string literals the mask blanked.
    lits: HashMap<usize, String>,
}

fn is_ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Blank `[a, b)` in `out` with spaces, preserving newlines.
fn blank(out: &mut [u8], a: usize, b: usize) {
    for slot in out.iter_mut().take(b.min(out.len())).skip(a) {
        if *slot != b'\n' {
            *slot = b' ';
        }
    }
}

/// Pass 1: comments → spaces, string contents → spaces (quotes kept,
/// text remembered), raw strings and char literals masked whole.
fn mask(src: &[u8]) -> (Vec<u8>, HashMap<usize, String>) {
    let mut out = src.to_vec();
    let mut lits = HashMap::new();
    let n = src.len();
    let mut i = 0;
    while i < n {
        let c = src[i];
        if c == b'/' && i + 1 < n && src[i + 1] == b'/' {
            let mut j = i;
            while j < n && src[j] != b'\n' {
                j += 1;
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'/' && i + 1 < n && src[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if src[j] == b'/' && j + 1 < n && src[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if src[j] == b'*' && j + 1 < n && src[j + 1] == b'/'
                {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'"' {
            let mut j = i + 1;
            while j < n && src[j] != b'"' {
                if src[j] == b'\\' {
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let text =
                String::from_utf8_lossy(&src[i + 1..j.min(n)]).into_owned();
            lits.insert(i, text);
            blank(&mut out, i + 1, j.min(n)); // keep both quotes
            i = (j + 1).min(n);
        } else if c == b'r' || c == b'b' {
            let prev = if i > 0 { src[i - 1] } else { 0 };
            let mut j = i + 1;
            if c == b'b' && j < n && src[j] == b'r' {
                j += 1;
            }
            let mut hashes = 0usize;
            while j < n && src[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            let starts_raw = !is_ident_byte(prev)
                && j < n
                && src[j] == b'"'
                && (c == b'r' || (i + 1 < n && src[i + 1] == b'r'));
            if starts_raw {
                // raw string r"…" / r#"…"# / br"…": mask it whole
                let mut close = vec![b'"'];
                close.extend(std::iter::repeat(b'#').take(hashes));
                let mut k = j + 1;
                while k < n && !src[k..].starts_with(&close) {
                    k += 1;
                }
                k = (k + close.len()).min(n);
                blank(&mut out, i, k);
                i = k;
            } else if c == b'b'
                && i + 1 < n
                && src[i + 1] == b'\''
                && !is_ident_byte(prev)
            {
                // byte char b'x'
                let mut j = i + 2;
                if j < n && src[j] == b'\\' {
                    j += 2;
                }
                while j < n && src[j] != b'\'' {
                    j += 1;
                }
                blank(&mut out, i, (j + 1).min(n));
                i = (j + 1).min(n);
            } else {
                i += 1;
            }
        } else if c == b'\'' {
            if i + 1 < n && src[i + 1] == b'\\' {
                // escaped char literal '\n', '\'', '\u{…}'
                let mut j = i + 3;
                while j < n && src[j] != b'\'' {
                    j += 1;
                }
                blank(&mut out, i, (j + 1).min(n));
                i = (j + 1).min(n);
            } else {
                // closing quote within a few bytes → char literal
                // ('x', multibyte 'é'); otherwise a lifetime ('a)
                let limit = (i + 6).min(n);
                let mut found = None;
                let mut k = i + 2;
                while k < limit {
                    if src[k] == b'\'' {
                        found = Some(k);
                        break;
                    }
                    k += 1;
                }
                if let Some(close) = found {
                    blank(&mut out, i, close + 1);
                    i = close + 1;
                } else {
                    i += 1; // lifetime
                }
            }
        } else {
            i += 1;
        }
    }
    (out, lits)
}

/// Pass 2: blank every `#[cfg(test)]`-gated item — the attribute, any
/// attributes after it, and the item body through its matching `}` (or
/// a terminating `;`).  Runs on already-masked text so comments and
/// strings cannot fake or hide an attribute.
fn cfg_test_mask(masked: &mut [u8]) {
    let src = masked.to_vec();
    let n = src.len();
    let skip_ws = |mut j: usize| {
        while j < n && (src[j] as char).is_ascii_whitespace() {
            j += 1;
        }
        j
    };
    let expect = |j: usize, tok: &[u8]| -> Option<usize> {
        let j = skip_ws(j);
        if src[j..].starts_with(tok) {
            Some(j + tok.len())
        } else {
            None
        }
    };
    let mut i = 0;
    while i < n {
        if src[i] != b'#' {
            i += 1;
            continue;
        }
        let matched = expect(i + 1, b"[")
            .and_then(|j| expect(j, b"cfg"))
            .and_then(|j| expect(j, b"("))
            .and_then(|j| expect(j, b"test"))
            .and_then(|j| expect(j, b")"))
            .and_then(|j| expect(j, b"]"));
        let Some(j) = matched else {
            i += 1;
            continue;
        };
        // skip any further attributes on the same item
        let mut k = skip_ws(j);
        while k < n && src[k] == b'#' {
            let k2 = skip_ws(k + 1);
            if k2 < n && src[k2] == b'[' {
                let mut depth = 1usize;
                let mut k3 = k2 + 1;
                while k3 < n && depth > 0 {
                    match src[k3] {
                        b'[' => depth += 1,
                        b']' => depth -= 1,
                        _ => {}
                    }
                    k3 += 1;
                }
                k = skip_ws(k3);
            } else {
                break;
            }
        }
        // scan to the item's first `{` or a terminating `;`
        while k < n && src[k] != b'{' && src[k] != b';' {
            k += 1;
        }
        if k < n && src[k] == b'{' {
            let mut depth = 1usize;
            k += 1;
            while k < n && depth > 0 {
                match src[k] {
                    b'{' => depth += 1,
                    b'}' => depth -= 1,
                    _ => {}
                }
                k += 1;
            }
        } else {
            k = (k + 1).min(n);
        }
        blank(masked, i, k);
        i = k;
    }
}

impl ScannedFile {
    /// Scan `src`, recorded under the root-relative path `rel`.
    pub fn scan(rel: &str, src: &str) -> ScannedFile {
        let raw = src.as_bytes();
        let (mut masked, lits) = mask(raw);
        cfg_test_mask(&mut masked);
        let mut cond = String::with_capacity(masked.len());
        let mut pos = Vec::with_capacity(masked.len());
        for (i, &c) in masked.iter().enumerate() {
            if !(c as char).is_ascii_whitespace() {
                cond.push(c as char);
                pos.push(i);
            }
        }
        let newlines = raw
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c == b'\n')
            .map(|(i, _)| i)
            .collect();
        ScannedFile {
            rel: rel.replace('\\', "/"),
            newlines,
            cond,
            pos,
            lits,
        }
    }

    /// 1-based line number of the condensed-stream index `cond_idx`.
    pub fn line_of(&self, cond_idx: usize) -> usize {
        let off = self.pos[cond_idx];
        self.newlines.partition_point(|&nl| nl < off) + 1
    }

    /// Every condensed-stream index where `pat` occurs.
    pub fn find_all(&self, pat: &str) -> Vec<usize> {
        let mut out = Vec::new();
        let mut start = 0;
        while let Some(k) = self.cond[start..].find(pat) {
            out.push(start + k);
            start += k + 1;
        }
        out
    }

    /// Original text of the string literal whose opening `"` sits at
    /// condensed index `cond_idx`, if one does.
    pub fn literal_at(&self, cond_idx: usize) -> Option<&str> {
        self.pos
            .get(cond_idx)
            .and_then(|off| self.lits.get(off))
            .map(String::as_str)
    }

    /// True when the condensed byte before `cond_idx` is part of an
    /// identifier (used to reject `fn tenant_gauge(` definition sites
    /// when looking for `tenant_gauge(` calls).
    pub fn preceded_by_ident(&self, cond_idx: usize) -> bool {
        cond_idx > 0
            && is_ident_byte(self.cond.as_bytes()[cond_idx - 1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_masked() {
        let f = ScannedFile::scan(
            "x.rs",
            r#"
// Instant::now() in a comment
/* and /* nested */ Instant::now() */
fn f() {
    let s = "Instant::now()";
    let r = r"Instant::now()";
}
"#,
        );
        assert!(!f.cond.contains("Instant::now()"));
        // quotes of plain strings survive; the raw string is gone
        assert!(f.cond.contains("lets=\"\";"));
    }

    #[test]
    fn literal_text_is_recoverable() {
        let f = ScannedFile::scan(
            "x.rs",
            "fn f(r: &Registry) { r.counter(\"my.metric\"); }",
        );
        let hits = f.find_all(".counter(");
        assert_eq!(hits.len(), 1);
        let quote = hits[0] + ".counter(".len();
        assert_eq!(&f.cond[quote..quote + 1], "\"");
        assert_eq!(f.literal_at(quote), Some("my.metric"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let f = ScannedFile::scan(
            "x.rs",
            "fn f<'a>(x: &'a str) -> char { let c = '\\''; 'x' }",
        );
        // lifetimes survive, char literals are blanked
        assert!(f.cond.contains("fnf<'a>(x:&'astr)"));
        assert!(!f.cond.contains("'x'"));
    }

    #[test]
    fn cfg_test_items_are_exempt() {
        let f = ScannedFile::scan(
            "x.rs",
            r#"
fn prod() { real_code(); }
#[cfg(test)]
mod tests {
    #[test]
    fn t() { std::time::Instant::now(); }
}
"#,
        );
        assert!(f.cond.contains("real_code()"));
        assert!(!f.cond.contains("Instant::now()"));
    }

    #[test]
    fn cfg_test_with_following_attributes() {
        let f = ScannedFile::scan(
            "x.rs",
            "#[cfg(test)]\n#[allow(dead_code)]\nfn helper() { bad(); }\nfn keep() {}",
        );
        assert!(!f.cond.contains("bad()"));
        assert!(f.cond.contains("fnkeep()"));
    }

    #[test]
    fn multiline_chains_condense() {
        let f = ScannedFile::scan(
            "x.rs",
            "fn f(m: &std::sync::Mutex<u8>) {\n    let _ = m\n        .lock()\n        .unwrap();\n}",
        );
        let hits = f.find_all(".lock().unwrap()");
        assert_eq!(hits.len(), 1);
        // the line reported is where the chain starts matching
        assert_eq!(f.line_of(hits[0]), 3);
    }

    #[test]
    fn line_mapping_is_exact() {
        let f = ScannedFile::scan("x.rs", "a\nbb\nccc\n");
        assert_eq!(f.line_of(0), 1);
        assert_eq!(f.line_of(1), 2);
        assert_eq!(f.line_of(3), 3);
    }
}
