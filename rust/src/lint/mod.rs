//! `pem-lint`: the project-native invariant analyzer.
//!
//! Five invariants, grown one per PR and previously enforced only by
//! reviewer memory, are machine-checked here (and on every commit by
//! the `lint-invariants` CI job — `cargo run --bin pem_lint`):
//!
//! * **L1 clock-discipline** — no `Instant::now()` /
//!   `SystemTime::now()` outside `obs/clock.rs`, `bench/` (which
//!   measures wall time by design) and `#[cfg(test)]` code.  Time
//!   flows through [`crate::obs::Clock`] / [`crate::obs::Stopwatch`].
//! * **L2 poison-safety** — no `.lock().unwrap()` (or the `RwLock`
//!   equivalents) in non-test code; locks go through
//!   `util::{lock,read,write}_poisonless` so one panicked holder
//!   cannot wedge every other tenant (the PR 8 bug class).
//! * **L3 wire-conformance** — the `TAG_*` frame-tag constants in
//!   `rpc/mod.rs` are unique and agree, bidirectionally, with the tag
//!   tables in `docs/WIRE_PROTOCOL.md`.
//! * **L4 metrics-conformance** — every metric-name literal the code
//!   registers appears in `docs/OBSERVABILITY.md`'s metric catalog,
//!   and vice versa.
//! * **L5 no-panic server paths** — `panic!` / `.unwrap()` /
//!   `.expect(` in non-test `service/`, `rpc/`, `net/`, `store/` code
//!   is held to the committed baseline `scripts/lint_baseline.txt`,
//!   which may only shrink.
//!
//! The scanner these run over is [`source::ScannedFile`] — masking,
//! not parsing; see that module.  `docs/STATIC_ANALYSIS.md` is the
//! operator-facing catalog.

pub mod source;

pub use source::ScannedFile;

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

/// One finding.  `line` is 0 for findings that are not anchored to a
/// source line (doc drift, baseline bookkeeping).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    /// Which invariant fired: `"L1"` … `"L5"`.
    pub lint: &'static str,
    /// Source-root-relative path (or a doc path for drift findings).
    pub path: String,
    /// 1-based line, 0 when not line-anchored.
    pub line: usize,
    /// Human explanation, including the fix.
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line > 0 {
            write!(
                f,
                "{} {}:{} {}",
                self.lint, self.path, self.line, self.detail
            )
        } else {
            write!(f, "{} {} {}", self.lint, self.path, self.detail)
        }
    }
}

/// Everything one lint run produced: hard failures plus non-fatal
/// warnings (stale baseline entries).
#[derive(Debug, Default)]
pub struct LintReport {
    /// Findings that fail the run.
    pub violations: Vec<Violation>,
    /// Non-fatal notices (e.g. a baseline entry the tree has already
    /// improved past — regenerate with `--write-baseline`).
    pub warnings: Vec<String>,
}

// ------------------------------------------------------------- L1

/// The one file allowed to touch `Instant`/`SystemTime` directly.
pub const CLOCK_FILE: &str = "obs/clock.rs";
/// Directory allowed to measure wall time directly: the bench harness
/// exists to time real execution (and stamps `created_unix` via
/// `SystemTime`).
pub const BENCH_DIR: &str = "bench/";

/// L1 clock-discipline: direct time reads outside the sanctioned
/// places.
pub fn check_clock_discipline(files: &[ScannedFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in files {
        if f.rel == CLOCK_FILE || f.rel.starts_with(BENCH_DIR) {
            continue;
        }
        for pat in ["Instant::now()", "SystemTime::now()"] {
            for k in f.find_all(pat) {
                out.push(Violation {
                    lint: "L1",
                    path: f.rel.clone(),
                    line: f.line_of(k),
                    detail: format!(
                        "{pat} in non-test code — route time through \
                         obs::Clock or obs::Stopwatch"
                    ),
                });
            }
        }
    }
    out
}

// ------------------------------------------------------------- L2

/// L2 poison-safety: raw lock-unwraps in non-test code.
pub fn check_poison_safety(files: &[ScannedFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    let fixes = [
        (".lock().unwrap()", "util::lock_poisonless"),
        (".read().unwrap()", "util::read_poisonless"),
        (".write().unwrap()", "util::write_poisonless"),
    ];
    for f in files {
        for (pat, fix) in fixes {
            for k in f.find_all(pat) {
                out.push(Violation {
                    lint: "L2",
                    path: f.rel.clone(),
                    line: f.line_of(k),
                    detail: format!(
                        "{pat} in non-test code — use {fix} so a \
                         poisoned lock recovers instead of wedging"
                    ),
                });
            }
        }
    }
    out
}

// ------------------------------------------------------------- L3

/// Where the frame-tag constants live.
pub const RPC_FILE: &str = "rpc/mod.rs";
/// The wire-protocol spec the tags must agree with.
pub const WIRE_DOC: &str = "docs/WIRE_PROTOCOL.md";

/// `TAG_JOIN_ACK` → `JoinAck` (the name the spec tables use).
fn tag_doc_name(tag_ident: &str) -> String {
    tag_ident
        .split('_')
        .map(|part| {
            let mut chars = part.chars();
            match chars.next() {
                Some(first) => {
                    first.to_ascii_uppercase().to_string()
                        + &chars.as_str().to_ascii_lowercase()
                }
                None => String::new(),
            }
        })
        .collect()
}

/// Frame tags declared in code: `(doc-style name, tag value, line)`,
/// parsed from `const TAG_<IDENT>: u8 = <N>;` items.
pub fn wire_tags(rpc: &ScannedFile) -> Vec<(String, u8, usize)> {
    let mut out = Vec::new();
    let bytes = rpc.cond.as_bytes();
    for k in rpc.find_all("constTAG_") {
        let mut j = k + "constTAG_".len();
        let ident_start = j;
        while j < bytes.len()
            && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_')
        {
            j += 1;
        }
        let ident = &rpc.cond[ident_start..j];
        if !rpc.cond[j..].starts_with(":u8=") {
            continue;
        }
        j += ":u8=".len();
        let num_start = j;
        while j < bytes.len() && bytes[j].is_ascii_digit() {
            j += 1;
        }
        if j == num_start || !rpc.cond[j..].starts_with(';') {
            continue;
        }
        let Ok(value) = rpc.cond[num_start..j].parse::<u8>() else {
            continue;
        };
        out.push((tag_doc_name(ident), value, rpc.line_of(k)));
    }
    out
}

/// Extract the text between the first pair of backticks in `cell`.
fn backticked(cell: &str) -> Option<&str> {
    let open = cell.find('`')?;
    let rest = &cell[open + 1..];
    let close = rest.find('`')?;
    Some(&rest[..close])
}

/// Tag rows of the spec's tables: any markdown table row whose first
/// cell is a number and whose second cell is a backticked frame name.
pub fn doc_wire_tags(doc: &str) -> Vec<(u8, String)> {
    let mut out = Vec::new();
    for line in doc.lines() {
        let line = line.trim();
        if !line.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        // split of "| a | b |" yields ["", "a", "b", ""]
        if cells.len() < 3 {
            continue;
        }
        let Ok(tag) = cells[1].parse::<u8>() else {
            continue;
        };
        if let Some(name) = backticked(cells[2]) {
            out.push((tag, name.to_string()));
        }
    }
    out
}

/// L3 wire-conformance: tags unique, documented, and nothing phantom
/// in the docs.
pub fn check_wire_conformance(
    rpc: &ScannedFile,
    doc: &str,
) -> Vec<Violation> {
    let mut out = Vec::new();
    let code = wire_tags(rpc);
    if code.is_empty() {
        out.push(Violation {
            lint: "L3",
            path: rpc.rel.clone(),
            line: 0,
            detail: "no `const TAG_*: u8` frame tags found — scanner \
                     and rpc module have drifted apart"
                .into(),
        });
        return out;
    }
    let mut by_value: BTreeMap<u8, (String, usize)> = BTreeMap::new();
    for (name, value, line) in &code {
        if let Some((prev, prev_line)) = by_value.get(value) {
            out.push(Violation {
                lint: "L3",
                path: rpc.rel.clone(),
                line: *line,
                detail: format!(
                    "tag {value} ({name}) duplicates {prev} \
                     (line {prev_line})"
                ),
            });
        } else {
            by_value.insert(*value, (name.clone(), *line));
        }
    }
    let mut doc_by_value: BTreeMap<u8, String> = BTreeMap::new();
    for (value, name) in doc_wire_tags(doc) {
        if let Some(prev) = doc_by_value.get(&value) {
            if *prev != name {
                out.push(Violation {
                    lint: "L3",
                    path: WIRE_DOC.into(),
                    line: 0,
                    detail: format!(
                        "tag {value} documented twice with different \
                         names: {prev} and {name}"
                    ),
                });
            }
        } else {
            doc_by_value.insert(value, name);
        }
    }
    for (value, (name, line)) in &by_value {
        match doc_by_value.get(value) {
            None => out.push(Violation {
                lint: "L3",
                path: rpc.rel.clone(),
                line: *line,
                detail: format!(
                    "tag {value} ({name}) is not documented in \
                     {WIRE_DOC}"
                ),
            }),
            Some(doc_name) if doc_name != name => out.push(Violation {
                lint: "L3",
                path: rpc.rel.clone(),
                line: *line,
                detail: format!(
                    "tag {value} is {name} in code but {doc_name} in \
                     {WIRE_DOC}"
                ),
            }),
            Some(_) => {}
        }
    }
    for (value, doc_name) in &doc_by_value {
        if !by_value.contains_key(value) {
            out.push(Violation {
                lint: "L3",
                path: WIRE_DOC.into(),
                line: 0,
                detail: format!(
                    "documents tag {value} ({doc_name}) which does not \
                     exist in {}",
                    rpc.rel
                ),
            });
        }
    }
    out
}

// ------------------------------------------------------------- L4

/// The metrics catalog the code-side names must agree with.
pub const OBS_DOC: &str = "docs/OBSERVABILITY.md";

/// Normalize a code-side metric-name literal: every `{…}` format
/// argument becomes `<*>` (`tenant.{id}.state` → `tenant.<*>.state`).
pub fn normalize_code_name(lit: &str) -> String {
    let mut out = String::with_capacity(lit.len());
    let mut rest = lit;
    while let Some(open) = rest.find('{') {
        out.push_str(&rest[..open]);
        match rest[open..].find('}') {
            Some(close) => {
                out.push_str("<*>");
                rest = &rest[open + close + 1..];
            }
            None => {
                rest = &rest[open..];
                break;
            }
        }
    }
    out.push_str(rest);
    out
}

/// Normalize a doc-side metric name: every `<…>` placeholder becomes
/// `<*>` (`tenant.<id>.state` → `tenant.<*>.state`).
pub fn normalize_doc_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    let mut rest = name;
    while let Some(open) = rest.find('<') {
        out.push_str(&rest[..open]);
        match rest[open..].find('>') {
            Some(close) => {
                out.push_str("<*>");
                rest = &rest[open + close + 1..];
            }
            None => {
                rest = &rest[open..];
                break;
            }
        }
    }
    out.push_str(rest);
    out
}

/// Metric names the code registers, normalized, with one witness
/// `(path, line)` each.  Recognized shapes:
///
/// * a string literal (or `&format!("…")`) directly inside a
///   `.counter(` / `.gauge(` / `.histogram(` / `.set_label(` /
///   `.label(` call;
/// * the first literal argument of a `tenant_gauge(` call (name
///   prefixed `tenant.<*>.`) or a `metric_name(` call — the two
///   sanctioned builders for names assembled away from the
///   instrument call.
pub fn code_metric_names(
    files: &[ScannedFile],
) -> BTreeMap<String, (String, usize)> {
    let mut out: BTreeMap<String, (String, usize)> = BTreeMap::new();
    let mut add = |name: String, path: &str, line: usize| {
        out.entry(name).or_insert_with(|| (path.to_string(), line));
    };
    let instrument_pats =
        [".counter(", ".gauge(", ".histogram(", ".set_label(", ".label("];
    let builder_pats = ["tenant_gauge(", "metric_name("];
    for f in files {
        for pat in instrument_pats {
            for k in f.find_all(pat) {
                let after = k + pat.len();
                if f.cond[after..].starts_with('"') {
                    if let Some(lit) = f.literal_at(after) {
                        add(
                            normalize_code_name(lit),
                            &f.rel,
                            f.line_of(k),
                        );
                    }
                } else if f.cond[after..].starts_with("&format!(\"") {
                    let q = after + "&format!(\"".len() - 1;
                    if let Some(lit) = f.literal_at(q) {
                        add(
                            normalize_code_name(lit),
                            &f.rel,
                            f.line_of(k),
                        );
                    }
                }
            }
        }
        for pat in builder_pats {
            for k in f.find_all(pat) {
                if f.preceded_by_ident(k) {
                    continue; // the `fn tenant_gauge` definition itself
                }
                // first string literal within the balanced call parens
                let mut depth = 0usize;
                let mut j = k + pat.len() - 1;
                let bytes = f.cond.as_bytes();
                let mut lit = None;
                while j < bytes.len() {
                    match bytes[j] {
                        b'(' => depth += 1,
                        b')' => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        b'"' => {
                            if let Some(text) = f.literal_at(j) {
                                lit = Some(text);
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                if let Some(text) = lit {
                    let name = if pat == "tenant_gauge(" {
                        format!("tenant.<*>.{text}")
                    } else {
                        normalize_code_name(text)
                    };
                    add(name, &f.rel, f.line_of(k));
                }
            }
        }
    }
    out
}

/// Metric names the catalog documents, normalized: the first cell of
/// every row of every markdown table whose header row contains both
/// `metric` and `kind`.
pub fn doc_metric_names(doc: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut in_table = false;
    for line in doc.lines() {
        let line = line.trim();
        if !line.starts_with('|') {
            in_table = false;
            continue;
        }
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        if cells.len() < 3 {
            continue;
        }
        let lowered = line.to_ascii_lowercase();
        if lowered.contains("metric") && lowered.contains("kind") {
            in_table = true;
            continue;
        }
        if !in_table || cells[1].chars().all(|c| c == '-' || c == ':') {
            continue;
        }
        if let Some(name) = backticked(cells[1]) {
            out.insert(normalize_doc_name(name));
        }
    }
    out
}

/// L4 metrics-conformance: code names ⊆ catalog and catalog ⊆ code
/// names.
pub fn check_metrics_conformance(
    files: &[ScannedFile],
    doc: &str,
) -> Vec<Violation> {
    let mut out = Vec::new();
    let code = code_metric_names(files);
    let documented = doc_metric_names(doc);
    if documented.is_empty() {
        out.push(Violation {
            lint: "L4",
            path: OBS_DOC.into(),
            line: 0,
            detail: "no metric catalog tables found (header cells \
                     `metric` + `kind`) — scanner and doc have \
                     drifted apart"
                .into(),
        });
        return out;
    }
    for (name, (path, line)) in &code {
        if !documented.contains(name) {
            out.push(Violation {
                lint: "L4",
                path: path.clone(),
                line: *line,
                detail: format!(
                    "metric `{name}` is not documented in {OBS_DOC}"
                ),
            });
        }
    }
    for name in &documented {
        if !code.contains_key(name) {
            out.push(Violation {
                lint: "L4",
                path: OBS_DOC.into(),
                line: 0,
                detail: format!(
                    "documents metric `{name}` which no code registers"
                ),
            });
        }
    }
    out
}

// ------------------------------------------------------------- L5

/// Directories whose non-test code must not panic: a panicking server
/// drops every connected tenant on the floor.
pub const SERVER_DIRS: [&str; 4] = ["service/", "rpc/", "net/", "store/"];

/// Panic-capable sites in non-test server-path code, by file:
/// `(line, pattern)` per site.
pub fn panic_sites(
    files: &[ScannedFile],
) -> BTreeMap<String, Vec<(usize, &'static str)>> {
    let mut out: BTreeMap<String, Vec<(usize, &'static str)>> =
        BTreeMap::new();
    for f in files {
        if !SERVER_DIRS.iter().any(|d| f.rel.starts_with(d)) {
            continue;
        }
        let mut sites = Vec::new();
        for pat in [".unwrap()", ".expect(", "panic!("] {
            for k in f.find_all(pat) {
                sites.push((f.line_of(k), pat));
            }
        }
        if !sites.is_empty() {
            sites.sort_unstable();
            out.insert(f.rel.clone(), sites);
        }
    }
    out
}

/// Parse `scripts/lint_baseline.txt`: `L5 <path> <count>` lines,
/// `#` comments and blank lines ignored.
pub fn parse_baseline(
    text: &str,
) -> Result<BTreeMap<String, usize>, String> {
    let mut out = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some("L5"), Some(path), Some(count), None) => {
                let count = count.parse::<usize>().map_err(|_| {
                    format!("baseline line {}: bad count", i + 1)
                })?;
                out.insert(path.to_string(), count);
            }
            _ => {
                return Err(format!(
                    "baseline line {}: expected `L5 <path> <count>`",
                    i + 1
                ))
            }
        }
    }
    Ok(out)
}

/// Render the current tree's L5 site counts as the baseline file.
pub fn format_baseline(
    sites: &BTreeMap<String, Vec<(usize, &'static str)>>,
) -> String {
    let mut out = String::from(
        "# pem-lint L5 no-panic baseline: panic-capable sites allowed\n\
         # per non-test server-path file.  This file may only shrink.\n\
         # Regenerate (after removing sites) with:\n\
         #     cargo run --bin pem_lint -- --write-baseline\n",
    );
    for (path, file_sites) in sites {
        out.push_str(&format!("L5 {} {}\n", path, file_sites.len()));
    }
    out
}

/// L5 no-panic server paths, held to the committed baseline.  New or
/// grown files fail; shrunken files only warn (regenerate the
/// baseline to lock in the improvement).
pub fn check_no_panic(
    files: &[ScannedFile],
    baseline: &BTreeMap<String, usize>,
) -> (Vec<Violation>, Vec<String>) {
    let mut violations = Vec::new();
    let mut warnings = Vec::new();
    let sites = panic_sites(files);
    for (path, file_sites) in &sites {
        let allowed = baseline.get(path).copied().unwrap_or(0);
        let count = file_sites.len();
        if count > allowed {
            let lines: Vec<String> = file_sites
                .iter()
                .map(|(line, pat)| format!("{line} ({pat})"))
                .collect();
            violations.push(Violation {
                lint: "L5",
                path: path.clone(),
                line: file_sites[0].0,
                detail: format!(
                    "{count} panic-capable sites, baseline allows \
                     {allowed} — lines {}.  Return a typed error \
                     instead; the baseline may only shrink",
                    lines.join(", ")
                ),
            });
        } else if count < allowed {
            warnings.push(format!(
                "L5 baseline stale: {path} allows {allowed} sites but \
                 only {count} remain — run with --write-baseline to \
                 lock in the improvement"
            ));
        }
    }
    for (path, allowed) in baseline {
        if *allowed > 0 && !sites.contains_key(path) {
            warnings.push(format!(
                "L5 baseline stale: {path} allows {allowed} sites but \
                 the file is clean (or gone) — run with \
                 --write-baseline"
            ));
        }
    }
    (violations, warnings)
}

// ------------------------------------------------------------- run

/// Everything a full lint run needs.  The binary builds this from the
/// filesystem; fixture tests build it from strings.
pub struct LintInput<'a> {
    /// Scanned `.rs` files, paths relative to the source root.
    pub files: Vec<ScannedFile>,
    /// Contents of `docs/WIRE_PROTOCOL.md` (None = L3 cannot run,
    /// which is itself a violation).
    pub wire_doc: Option<&'a str>,
    /// Contents of `docs/OBSERVABILITY.md` (None = L4 cannot run,
    /// which is itself a violation).
    pub obs_doc: Option<&'a str>,
    /// Contents of `scripts/lint_baseline.txt` (None = empty
    /// baseline: every L5 site is a violation).
    pub baseline: Option<&'a str>,
}

/// Run all five lints and collect the report.
pub fn run(input: &LintInput<'_>) -> LintReport {
    let mut report = LintReport::default();
    report
        .violations
        .extend(check_clock_discipline(&input.files));
    report.violations.extend(check_poison_safety(&input.files));
    match (
        input.files.iter().find(|f| f.rel == RPC_FILE),
        input.wire_doc,
    ) {
        (Some(rpc), Some(doc)) => {
            report.violations.extend(check_wire_conformance(rpc, doc));
        }
        (None, _) => report.violations.push(Violation {
            lint: "L3",
            path: RPC_FILE.into(),
            line: 0,
            detail: "file not found under the source root".into(),
        }),
        (_, None) => report.violations.push(Violation {
            lint: "L3",
            path: WIRE_DOC.into(),
            line: 0,
            detail: "spec not found — wire tags cannot be checked"
                .into(),
        }),
    }
    match input.obs_doc {
        Some(doc) => report
            .violations
            .extend(check_metrics_conformance(&input.files, doc)),
        None => report.violations.push(Violation {
            lint: "L4",
            path: OBS_DOC.into(),
            line: 0,
            detail: "catalog not found — metric names cannot be \
                     checked"
                .into(),
        }),
    }
    let baseline = match input.baseline {
        Some(text) => match parse_baseline(text) {
            Ok(b) => b,
            Err(e) => {
                report.violations.push(Violation {
                    lint: "L5",
                    path: "scripts/lint_baseline.txt".into(),
                    line: 0,
                    detail: e,
                });
                BTreeMap::new()
            }
        },
        None => BTreeMap::new(),
    };
    let (violations, warnings) = check_no_panic(&input.files, &baseline);
    report.violations.extend(violations);
    report.warnings.extend(warnings);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(rel: &str, src: &str) -> ScannedFile {
        ScannedFile::scan(rel, src)
    }

    // ---------------------------------------------------- L1 fixtures

    #[test]
    fn l1_fires_on_direct_time_reads() {
        let files = vec![scan(
            "engine/foo.rs",
            "fn f() { let t = std::time::Instant::now(); }\n\
             fn g() { let s = std::time::SystemTime::now(); }",
        )];
        let v = check_clock_discipline(&files);
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].lint, "L1");
        assert_eq!(v[0].path, "engine/foo.rs");
        assert_eq!(v[0].line, 1);
        assert_eq!(v[1].line, 2);
    }

    #[test]
    fn l1_exempts_clock_file_bench_and_test_code() {
        let files = vec![
            scan(CLOCK_FILE, "fn f() { Instant::now(); }"),
            scan("bench/mod.rs", "fn f() { Instant::now(); }"),
            scan(
                "engine/foo.rs",
                "#[cfg(test)]\nmod tests {\n  #[test]\n  fn t() { \
                 std::time::Instant::now(); }\n}",
            ),
            // comments and strings never fire
            scan(
                "engine/bar.rs",
                "// Instant::now()\nfn f() { let s = \
                 \"Instant::now()\"; }",
            ),
        ];
        assert!(check_clock_discipline(&files).is_empty());
    }

    // ---------------------------------------------------- L2 fixtures

    #[test]
    fn l2_fires_on_raw_lock_unwraps_even_multiline() {
        let files = vec![scan(
            "service/foo.rs",
            "fn f(m: &std::sync::Mutex<u8>, l: &std::sync::RwLock<u8>) \
             {\n    let _ = m\n        .lock()\n        .unwrap();\n    \
             let _ = l.read().unwrap();\n    let _ = \
             l.write().unwrap();\n}",
        )];
        let v = check_poison_safety(&files);
        assert_eq!(v.len(), 3);
        assert!(v[0].detail.contains("lock_poisonless"));
        assert!(v[1].detail.contains("read_poisonless"));
        assert!(v[2].detail.contains("write_poisonless"));
    }

    #[test]
    fn l2_exempts_test_code() {
        let files = vec![scan(
            "service/foo.rs",
            "fn ok() {}\n#[cfg(test)]\nmod tests {\n  #[test]\n  fn t() \
             { m().lock().unwrap(); }\n}",
        )];
        assert!(check_poison_safety(&files).is_empty());
    }

    // ---------------------------------------------------- L3 fixtures

    const RPC_FIXTURE: &str = "const TAG_JOIN: u8 = 1;\n\
         const TAG_JOIN_ACK: u8 = 2;\n\
         pub const TAG_PLAN_RESULT: u8 = 29;\n";

    const WIRE_FIXTURE: &str = "\
         | tag | frame | direction | fields |\n\
         |---|---|---|---|\n\
         | 1 | `Join` | a | b |\n\
         | 2 | `JoinAck` | a | b |\n\
         | 29 | `PlanResult` | a | b |\n";

    #[test]
    fn l3_parses_tags_and_passes_when_in_sync() {
        let rpc = scan(RPC_FILE, RPC_FIXTURE);
        let tags = wire_tags(&rpc);
        assert_eq!(
            tags,
            vec![
                ("Join".to_string(), 1, 1),
                ("JoinAck".to_string(), 2, 2),
                ("PlanResult".to_string(), 29, 3),
            ]
        );
        assert!(check_wire_conformance(&rpc, WIRE_FIXTURE).is_empty());
    }

    #[test]
    fn l3_detects_undocumented_tag() {
        let rpc = scan(
            RPC_FILE,
            &format!("{RPC_FIXTURE}const TAG_NEW_THING: u8 = 30;\n"),
        );
        let v = check_wire_conformance(&rpc, WIRE_FIXTURE);
        assert_eq!(v.len(), 1);
        assert!(v[0].detail.contains("tag 30 (NewThing) is not documented"));
        assert_eq!(v[0].line, 4);
    }

    #[test]
    fn l3_detects_phantom_doc_tag_and_duplicate_code_tag() {
        let rpc = scan(
            RPC_FILE,
            &format!("{RPC_FIXTURE}const TAG_CLASH: u8 = 1;\n"),
        );
        let doc =
            format!("{WIRE_FIXTURE}| 77 | `Ghost` | a | b |\n");
        let v = check_wire_conformance(&rpc, &doc);
        let details: Vec<&str> =
            v.iter().map(|x| x.detail.as_str()).collect();
        assert!(details.iter().any(|d| d.contains("duplicates Join")));
        assert!(details
            .iter()
            .any(|d| d.contains("documents tag 77 (Ghost)")));
    }

    #[test]
    fn l3_detects_name_mismatch() {
        let rpc = scan(RPC_FILE, RPC_FIXTURE);
        let doc = WIRE_FIXTURE.replace("`JoinAck`", "`JoinReply`");
        let v = check_wire_conformance(&rpc, &doc);
        assert_eq!(v.len(), 1);
        assert!(v[0]
            .detail
            .contains("tag 2 is JoinAck in code but JoinReply"));
    }

    // ---------------------------------------------------- L4 fixtures

    const OBS_FIXTURE: &str = "\
         some prose.\n\n\
         | metric | kind | meaning |\n\
         |---|---|---|\n\
         | `ops` | counter | stuff |\n\
         | `node.<i>.busy_ns` | gauge | stuff |\n\
         | `tenant.<id>.state` | gauge | stuff |\n\n\
         more prose.\n";

    fn metric_fixture_files() -> Vec<ScannedFile> {
        vec![scan(
            "service/foo.rs",
            "fn f(reg: &Registry, id: u32) {\n\
             reg.counter(\"ops\").inc();\n\
             reg.gauge(&format!(\"node.{id}.busy_ns\")).set(1);\n\
             reg.gauge(&crate::obs::tenant_gauge(id, \"state\")).set(1);\n\
             }\n",
        )]
    }

    #[test]
    fn l4_normalizes_format_args_and_tenant_gauge() {
        let names = code_metric_names(&metric_fixture_files());
        let keys: Vec<&str> =
            names.keys().map(String::as_str).collect();
        assert_eq!(
            keys,
            vec!["node.<*>.busy_ns", "ops", "tenant.<*>.state"]
        );
        assert!(check_metrics_conformance(
            &metric_fixture_files(),
            OBS_FIXTURE
        )
        .is_empty());
    }

    #[test]
    fn l4_detects_undocumented_metric() {
        let mut files = metric_fixture_files();
        files.push(scan(
            "service/bar.rs",
            "fn g(reg: &Registry) { reg.counter(\"sneaky\").inc(); }",
        ));
        let v = check_metrics_conformance(&files, OBS_FIXTURE);
        assert_eq!(v.len(), 1);
        assert!(v[0].detail.contains("`sneaky` is not documented"));
        assert_eq!(v[0].path, "service/bar.rs");
    }

    #[test]
    fn l4_detects_phantom_doc_metric() {
        let doc = format!(
            "{OBS_FIXTURE}\n| metric | kind | meaning |\n|---|---|---|\n\
             | `ghost.metric` | counter | stuff |\n"
        );
        let v = check_metrics_conformance(&metric_fixture_files(), &doc);
        assert_eq!(v.len(), 1);
        assert!(v[0].detail.contains("`ghost.metric`"));
        assert_eq!(v[0].path, OBS_DOC);
    }

    #[test]
    fn l4_ignores_the_builder_definitions_themselves() {
        let files = vec![scan(
            "obs/registry.rs",
            "pub fn tenant_gauge(id: u32, field: &str) -> String {\n\
             format!(\"tenant.{id}.{field}\")\n}\n\
             pub const fn metric_name(name: &'static str) -> &'static \
             str { name }\n",
        )];
        assert!(code_metric_names(&files).is_empty());
    }

    // ---------------------------------------------------- L5 fixtures

    #[test]
    fn l5_counts_sites_and_honors_baseline() {
        let files = vec![
            scan(
                "rpc/foo.rs",
                "fn f(x: Option<u8>) { x.unwrap(); \
                 x.expect(\"boom\"); }",
            ),
            scan("engine/foo.rs", "fn f(x: Option<u8>) { x.unwrap(); }"),
        ];
        // engine/ is not a server dir
        let sites = panic_sites(&files);
        assert_eq!(sites.len(), 1);
        assert_eq!(sites["rpc/foo.rs"].len(), 2);
        // no baseline: violation
        let (v, w) = check_no_panic(&files, &BTreeMap::new());
        assert_eq!(v.len(), 1);
        assert!(v[0].detail.contains("2 panic-capable sites"));
        assert!(w.is_empty());
        // exact baseline: clean
        let exact = parse_baseline("L5 rpc/foo.rs 2\n").unwrap();
        let (v, w) = check_no_panic(&files, &exact);
        assert!(v.is_empty() && w.is_empty());
        // generous baseline: stale warning, no violation
        let generous =
            parse_baseline("L5 rpc/foo.rs 5\nL5 rpc/gone.rs 3\n")
                .unwrap();
        let (v, w) = check_no_panic(&files, &generous);
        assert!(v.is_empty());
        assert_eq!(w.len(), 2);
        assert!(w.iter().all(|x| x.contains("stale")));
    }

    #[test]
    fn l5_panics_in_test_code_are_exempt() {
        let files = vec![scan(
            "store/foo.rs",
            "fn ok() {}\n#[cfg(test)]\nmod tests {\n  #[test]\n  fn t() \
             { Some(1).unwrap(); panic!(\"x\"); }\n}",
        )];
        assert!(panic_sites(&files).is_empty());
    }

    #[test]
    fn baseline_roundtrips_through_format_and_parse() {
        let files = vec![scan(
            "net/foo.rs",
            "fn f() { panic!(\"a\"); Some(1).unwrap(); }",
        )];
        let sites = panic_sites(&files);
        let text = format_baseline(&sites);
        let parsed = parse_baseline(&text).unwrap();
        assert_eq!(parsed["net/foo.rs"], 2);
        assert!(parse_baseline("garbage line\n").is_err());
        assert!(parse_baseline("# comment\n\n").unwrap().is_empty());
    }

    // ------------------------------------- the real tree's artifacts

    /// The committed spec stays parseable and in sync with the real
    /// `rpc/mod.rs` — this is the L3 gate runnable without a
    /// filesystem walk.
    #[test]
    fn real_wire_protocol_doc_matches_rpc_module() {
        let rpc = scan(
            RPC_FILE,
            include_str!(concat!(
                env!("CARGO_MANIFEST_DIR"),
                "/src/rpc/mod.rs"
            )),
        );
        let doc = include_str!(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../docs/WIRE_PROTOCOL.md"
        ));
        let tags = wire_tags(&rpc);
        assert!(tags.len() >= 29, "found only {} tags", tags.len());
        let v = check_wire_conformance(&rpc, doc);
        assert!(v.is_empty(), "L3 drift: {v:?}");
    }

    /// The committed catalog parses and contains the core names.
    #[test]
    fn real_observability_doc_has_a_catalog() {
        let doc = include_str!(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../docs/OBSERVABILITY.md"
        ));
        let names = doc_metric_names(doc);
        for expect in [
            "store.faults",
            "reactor.wakeups",
            "tenant.<*>.state",
            "node.<*>.busy_ns",
            "makespan_ns",
        ] {
            assert!(names.contains(expect), "catalog lost `{expect}`");
        }
    }

    /// The committed L5 baseline parses and only names real server
    /// dirs.
    #[test]
    fn real_baseline_parses() {
        let text = include_str!(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../scripts/lint_baseline.txt"
        ));
        let baseline = parse_baseline(text).unwrap();
        assert!(!baseline.is_empty());
        for path in baseline.keys() {
            assert!(
                SERVER_DIRS.iter().any(|d| path.starts_with(d)),
                "baseline entry {path} outside server dirs"
            );
        }
    }
}
