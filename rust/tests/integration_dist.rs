//! End-to-end tests of the distributed runtime: a full blocking-based
//! match workflow (generate → partition → task generation → parallel
//! match) executed through **real localhost TCP services** — workflow,
//! data, and ≥ 2 match-service nodes speaking the `pem::rpc` wire
//! protocol — validated against the in-process thread engine on the
//! same seed.
//!
//! The fault-injection half (PR 3) routes both planes through a
//! [`ChaosTransport`] — a byte-mangling TCP forwarder that splits
//! writes down to single bytes, stalls mid-frame, and cuts
//! connections mid-frame — and holds a 4-node *batched* run to the
//! thread engine's exact result: the readiness-driven servers must
//! reassemble frames from any chunking, and the scheduler must
//! neither lose nor double-complete a task, whatever the injected
//! faults do (in the spirit of deterministic failpoint testing).

use pem::cluster::ComputingEnv;
use pem::coordinator::workflow::EngineChoice;
use pem::coordinator::{
    run_workflow, PartitioningChoice, Policy, WorkflowConfig,
};
use pem::datagen::GeneratorConfig;
use pem::engine::dist;
use pem::matching::{MatchStrategy, StrategyKind};
use pem::model::EntityId;
use pem::partition::{generate_tasks, partition_size_based};
use pem::service::{
    announce_replica, run_match_node, DataServiceServer, MatchNodeConfig,
    WorkflowServerConfig, WorkflowServiceServer,
};
use pem::store::{DataService, SpillStore};
use pem::util::GIB;
use pem::worker::{RustExecutor, TaskExecutor};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn blocking_cfg(kind: StrategyKind, max: usize, min: usize) -> WorkflowConfig {
    let mut cfg = WorkflowConfig::blocking_based(kind);
    if let PartitioningChoice::BlockingBased {
        max_size, min_size, ..
    } = &mut cfg.partitioning
    {
        *max_size = Some(max);
        *min_size = min;
    }
    cfg
}

/// Fault profile of one [`ChaosTransport`] direction.
#[derive(Clone, Copy)]
struct ChaosConfig {
    /// 1-in-N chance to stall 1–20 ms before forwarding a chunk
    /// (0 = never stall).
    stall_one_in: usize,
    /// Cut the connection (both directions, mid-frame with
    /// overwhelming probability) after forwarding this many bytes.
    disconnect_after: Option<u64>,
}

/// A deterministic byte-mangling TCP forwarder: everything a client
/// sends is re-chunked (down to single bytes, so length prefixes get
/// split), optionally stalled, and optionally cut mid-frame, before
/// reaching the upstream server — and the same on the way back.  The
/// readiness-driven servers and the blocking clients must survive all
/// of it; the run's *result* must not change.
struct ChaosTransport;

impl ChaosTransport {
    /// Start a forwarder to `upstream`; returns the address clients
    /// should connect to.  Each proxied connection gets its own
    /// deterministic fault stream derived from `seed`.
    fn start(
        upstream: String,
        seed: u64,
        cfg: ChaosConfig,
    ) -> std::net::SocketAddr {
        let listener =
            std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let mut conn_seq = 0u64;
            for client in listener.incoming() {
                let Ok(client) = client else { break };
                conn_seq += 1;
                let Ok(server) =
                    std::net::TcpStream::connect(&upstream)
                else {
                    continue; // upstream gone: drop the client conn
                };
                let c2 = client.try_clone().unwrap();
                let s2 = server.try_clone().unwrap();
                let conn_seed = seed
                    ^ conn_seq.wrapping_mul(0x9e37_79b9_7f4a_7c15);
                std::thread::spawn(move || {
                    chaos_pump(
                        client,
                        s2,
                        pem::util::Rng::new(conn_seed),
                        cfg,
                    )
                });
                std::thread::spawn(move || {
                    chaos_pump(
                        server,
                        c2,
                        pem::util::Rng::new(conn_seed ^ 0xFF),
                        cfg,
                    )
                });
            }
        });
        addr
    }
}

/// One direction of a proxied connection: read arbitrary-size chunks,
/// forward them as several short writes, stall occasionally, cut the
/// whole connection once the byte budget is spent.
fn chaos_pump(
    mut from: std::net::TcpStream,
    mut to: std::net::TcpStream,
    mut rng: pem::util::Rng,
    cfg: ChaosConfig,
) {
    use std::io::{Read, Write};
    let mut buf = [0u8; 4096];
    let mut forwarded = 0u64;
    'pump: loop {
        // arbitrary read sizes: 1-byte reads split length prefixes on
        // the receiving session state machine
        let max = if rng.gen_bool(0.3) {
            1 + rng.gen_range(7)
        } else {
            1 + rng.gen_range(buf.len() - 1)
        };
        let n = match from.read(&mut buf[..max]) {
            Ok(0) | Err(_) => break,
            Ok(n) => n,
        };
        if cfg.stall_one_in > 0 && rng.gen_range(cfg.stall_one_in) == 0 {
            std::thread::sleep(Duration::from_millis(
                (1 + rng.gen_range(19)) as u64,
            ));
        }
        // short writes: forward in several small slices
        let mut off = 0;
        while off < n {
            let chunk = 1 + rng.gen_range(n - off);
            if to.write_all(&buf[off..off + chunk]).is_err() {
                break 'pump;
            }
            off += chunk;
        }
        forwarded += n as u64;
        if let Some(limit) = cfg.disconnect_after {
            if forwarded >= limit {
                break; // mid-frame cut: both sides torn down below
            }
        }
    }
    let _ = from.shutdown(std::net::Shutdown::Both);
    let _ = to.shutdown(std::net::Shutdown::Both);
}

/// Order-normalize a correspondence set for exact comparison.
fn norm_pairs(
    cs: &[pem::model::Correspondence],
) -> Vec<(EntityId, EntityId)> {
    let mut r = pem::model::MatchResult::new();
    for &c in cs {
        r.add(c);
    }
    let mut pairs: Vec<(EntityId, EntityId)> =
        r.iter().map(|c| c.pair()).collect();
    pairs.sort_unstable();
    pairs
}

/// The acceptance-criteria test: a blocking-based workflow through real
/// sockets with two match-service nodes produces correspondences
/// identical to the thread engine on the same seed, and the traffic
/// stats show nonzero bytes actually delivered over TCP.
#[test]
fn dist_workflow_matches_thread_engine_exactly() {
    let data = GeneratorConfig::tiny()
        .with_entities(600)
        .with_seed(42)
        .generate();
    let ce = ComputingEnv::new(2, 2, GIB); // 2 match services × 2 workers
    let base = blocking_cfg(StrategyKind::Wam, 150, 30).with_cache(8);

    let threads = run_workflow(
        &data,
        &base.clone().with_engine(EngineChoice::Threads),
        &ce,
    )
    .unwrap();
    let dist = run_workflow(
        &data,
        &base.with_engine(EngineChoice::Distributed),
        &ce,
    )
    .unwrap();

    // identical structure …
    assert_eq!(dist.n_partitions, threads.n_partitions);
    assert_eq!(dist.n_tasks, threads.n_tasks);
    assert_eq!(dist.metrics.tasks, threads.metrics.tasks);
    assert_eq!(dist.metrics.comparisons, threads.metrics.comparisons);

    // … and an identical merged match result, similarity included:
    // the wire round trip reconstructs features losslessly, so every
    // pair must score exactly the same
    assert_eq!(dist.result.len(), threads.result.len());
    for c in threads.result.iter() {
        assert_eq!(
            dist.result.similarity(c.e1, c.e2),
            Some(c.sim),
            "pair ({}, {}) differs across engines",
            c.e1,
            c.e2
        );
    }

    // sanity: the workflow really found the injected duplicates
    let q = dist.result.quality(&data.truth);
    assert!(q.recall > 0.7, "recall {}", q.recall);

    // real socket traffic: delivered bytes from actual TCP transfers
    assert!(
        dist.metrics.bytes_fetched > 0,
        "data-plane TrafficStats must show delivered wire bytes"
    );
    assert!(dist.metrics.control_messages > dist.n_tasks as u64);
    assert!(dist.metrics.cache_hits > 0, "partition caches engaged");
}

/// The replicated data plane end to end: a full workflow on 2 data
/// replicas and 2 match-service nodes is result-identical to the
/// thread engine, every data server carries traffic, and the
/// per-replica byte accounting adds up.
#[test]
fn dist_replicated_run_matches_thread_engine_exactly() {
    let data = GeneratorConfig::tiny()
        .with_entities(600)
        .with_seed(42)
        .generate();
    let ce = ComputingEnv::new(2, 2, GIB); // 2 match services × 2 workers
    let base = blocking_cfg(StrategyKind::Wam, 150, 30).with_cache(8);

    let threads = run_workflow(
        &data,
        &base.clone().with_engine(EngineChoice::Threads),
        &ce,
    )
    .unwrap();
    let dist = run_workflow(
        &data,
        &base
            .with_engine(EngineChoice::Distributed)
            .with_data_replicas(2),
        &ce,
    )
    .unwrap();

    assert_eq!(dist.metrics.tasks, threads.metrics.tasks);
    assert_eq!(dist.metrics.comparisons, threads.metrics.comparisons);
    assert_eq!(dist.result.len(), threads.result.len());
    for c in threads.result.iter() {
        assert_eq!(
            dist.result.similarity(c.e1, c.e2),
            Some(c.sim),
            "pair ({}, {}) differs with a replicated data plane",
            c.e1,
            c.e2
        );
    }
}

/// Data-plane failover end to end: two data replicas serve a 2-node
/// run; one replica is killed mid-run, the nodes fail over to the
/// surviving server, and the merged result is still identical to the
/// thread engine on the same seed.
#[test]
fn dist_replica_killed_mid_run_fails_over_and_completes() {
    let data = GeneratorConfig::tiny()
        .with_entities(500)
        .with_seed(13)
        .generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, 40);
    let tasks = generate_tasks(&parts);
    let n_tasks = tasks.len();
    assert!(n_tasks > 20, "need a long enough run to kill mid-way");
    let store = Arc::new(DataService::build(&data.dataset, &parts));

    // reference result from the thread engine
    let exec = RustExecutor::new(MatchStrategy::new(StrategyKind::Wam));
    let reference = pem::engine::threads::run(
        &ComputingEnv::new(1, 2, GIB),
        &parts,
        tasks.clone(),
        &store,
        &exec,
        pem::engine::threads::ThreadConfig::default(),
    );

    // primary + one synced replica, both announced to the coordinator
    let primary =
        DataServiceServer::start(store.clone(), "127.0.0.1:0").unwrap();
    let replica = DataServiceServer::start_replica(
        "127.0.0.1:0",
        &primary.addr().to_string(),
        Duration::from_secs(10),
    )
    .unwrap();
    assert!(replica.wait_synced(Duration::from_secs(30)));
    let wf_srv = WorkflowServiceServer::start(
        tasks,
        WorkflowServerConfig::default(),
        "127.0.0.1:0",
    )
    .unwrap();
    let wf_addr = wf_srv.addr().to_string();
    for srv in [&primary, &replica] {
        announce_replica(
            &wf_addr,
            &srv.addr().to_string(),
            &srv.partition_ids(),
            Duration::from_secs(5),
        )
        .unwrap();
    }

    // two match nodes; small caches keep wire fetches coming so the
    // kill below is guaranteed to be felt
    let node_handles: Vec<_> = (0..2)
        .map(|i| {
            let mut cfg = MatchNodeConfig::new(
                wf_addr.clone(),
                primary.addr().to_string(),
            );
            cfg.data_addrs.push(replica.addr().to_string());
            cfg.name = format!("failover-node-{i}");
            cfg.threads = 2;
            cfg.cache_capacity = 2;
            let exec: Arc<dyn TaskExecutor> = Arc::new(RustExecutor::new(
                MatchStrategy::new(StrategyKind::Wam),
            ));
            std::thread::spawn(move || run_match_node(&cfg, exec))
        })
        .collect();

    // kill the replica once the run is ~20% through
    let deadline = Instant::now() + Duration::from_secs(60);
    while wf_srv.completed() < n_tasks / 5 {
        assert!(Instant::now() < deadline, "run never got going");
        std::thread::sleep(Duration::from_millis(2));
    }
    replica.shutdown();

    let mut reports = Vec::new();
    for h in node_handles {
        reports.push(h.join().expect("node thread").expect("node report"));
    }
    assert!(wf_srv.wait_done(Duration::from_secs(60)));
    let report = wf_srv.finish();
    primary.shutdown();

    assert_eq!(report.completed_tasks, n_tasks, "every task completed");
    for r in &reports {
        assert!(!r.crashed, "failover must not take a node down");
        assert_eq!(r.fetches_per_replica.len(), 2);
    }
    // at least one node actually exercised the failover path (the
    // replica had served ~half the traffic before the kill)
    let failovers: u64 = reports.iter().map(|r| r.replica_failovers).sum();
    assert!(failovers >= 1, "no node failed over: {reports:?}");

    // and the failure changed nothing about the merged result
    let norm = |cs: &[pem::model::Correspondence]| {
        let mut r = pem::model::MatchResult::new();
        for &c in cs {
            r.add(c);
        }
        let mut pairs: Vec<(EntityId, EntityId)> =
            r.iter().map(|c| c.pair()).collect();
        pairs.sort_unstable();
        pairs
    };
    assert_eq!(
        norm(&report.correspondences),
        norm(&reference.correspondences)
    );
}

/// Failure handling (paper §4) through the wire: a node that stops
/// heartbeating mid-run has its in-flight task re-queued by the
/// workflow service, and the surviving node still completes the full
/// workflow with the correct result.
#[test]
fn dist_node_failure_requeues_and_completes() {
    let data = GeneratorConfig::tiny()
        .with_entities(400)
        .with_seed(7)
        .generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, 40);
    let tasks = generate_tasks(&parts);
    let n_tasks = tasks.len();
    assert!(n_tasks > 20, "need enough tasks to guarantee overlap");
    let store = Arc::new(DataService::build(&data.dataset, &parts));

    // reference result from the thread engine
    let exec = RustExecutor::new(MatchStrategy::new(StrategyKind::Wam));
    let reference = pem::engine::threads::run(
        &ComputingEnv::new(1, 2, GIB),
        &parts,
        tasks.clone(),
        &store,
        &exec,
        pem::engine::threads::ThreadConfig::default(),
    );

    // distributed run: node 1 crashes after completing one task,
    // abandoning its next assignment without reporting
    let ce = ComputingEnv::new(2, 1, GIB);
    let shared_exec: Arc<dyn TaskExecutor> =
        Arc::new(RustExecutor::new(MatchStrategy::new(StrategyKind::Wam)));
    let out = dist::run(
        &ce,
        &parts,
        tasks,
        store.clone(),
        shared_exec,
        dist::DistConfig {
            cache_capacity: 4,
            policy: Policy::Affinity,
            heartbeat_timeout: Duration::from_millis(250),
            heartbeat_interval: Duration::from_millis(25),
            fail_node_after: vec![(1, 1)],
            ..dist::DistConfig::default()
        },
    )
    .unwrap();

    assert_eq!(out.metrics.tasks, n_tasks, "every task completed");
    assert!(
        out.workflow.requeued_tasks >= 1,
        "the dead node's in-flight task must have been re-queued"
    );
    assert_eq!(
        out.node_reports.iter().filter(|r| r.crashed).count(),
        1,
        "exactly one node simulated the crash"
    );

    // the failure must not change the merged result
    let norm = |cs: &[pem::model::Correspondence]| {
        let mut r = pem::model::MatchResult::new();
        for &c in cs {
            r.add(c);
        }
        let mut pairs: Vec<(EntityId, EntityId)> =
            r.iter().map(|c| c.pair()).collect();
        pairs.sort_unstable();
        pairs
    };
    assert_eq!(
        norm(&out.correspondences),
        norm(&reference.correspondences)
    );
}

/// Batched assignment (protocol v3) through the full workflow API: a
/// 2-node run pulling 4 tasks per round trip is result-identical to
/// the thread engine — batching changes the control-plane shape, never
/// the output.
#[test]
fn dist_batched_run_matches_thread_engine_exactly() {
    let data = GeneratorConfig::tiny()
        .with_entities(600)
        .with_seed(42)
        .generate();
    let ce = ComputingEnv::new(2, 2, GIB);
    let base = blocking_cfg(StrategyKind::Wam, 150, 30).with_cache(8);

    let threads = run_workflow(
        &data,
        &base.clone().with_engine(EngineChoice::Threads),
        &ce,
    )
    .unwrap();
    let dist = run_workflow(
        &data,
        &base.with_engine(EngineChoice::Distributed).with_batch(4),
        &ce,
    )
    .unwrap();

    assert_eq!(dist.metrics.tasks, threads.metrics.tasks);
    assert_eq!(dist.metrics.comparisons, threads.metrics.comparisons);
    assert_eq!(dist.result.len(), threads.result.len());
    for c in threads.result.iter() {
        assert_eq!(
            dist.result.similarity(c.e1, c.e2),
            Some(c.sim),
            "pair ({}, {}) differs under batched assignment",
            c.e1,
            c.e2
        );
    }
}

/// The PR-3 acceptance test: a **4-node batched run under fault
/// injection** — every control and data connection passes through a
/// [`ChaosTransport`] that splits writes down to single bytes and
/// stalls mid-frame, and the chaotic data path additionally cuts
/// connections mid-frame (forcing failover to the direct replica) —
/// must complete every task exactly once with a merged result
/// identical to the thread engine on the same seed.
#[test]
fn dist_batched_chaos_run_matches_thread_engine() {
    let data = GeneratorConfig::tiny()
        .with_entities(600)
        .with_seed(42)
        .generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, 40);
    let tasks = generate_tasks(&parts);
    let n_tasks = tasks.len();
    let store = Arc::new(DataService::build(&data.dataset, &parts));

    // reference result from the thread engine
    let exec = RustExecutor::new(MatchStrategy::new(StrategyKind::Wam));
    let reference = pem::engine::threads::run(
        &ComputingEnv::new(1, 2, GIB),
        &parts,
        tasks.clone(),
        &store,
        &exec,
        pem::engine::threads::ThreadConfig::default(),
    );

    let primary =
        DataServiceServer::start(store.clone(), "127.0.0.1:0").unwrap();
    let wf_srv = WorkflowServiceServer::start(
        tasks,
        WorkflowServerConfig {
            policy: Policy::Affinity,
            // stalls are ≤ 20 ms; keep spurious failure detection out
            heartbeat_timeout: Duration::from_secs(3),
            ..WorkflowServerConfig::default()
        },
        "127.0.0.1:0",
    )
    .unwrap();
    let wf_addr = wf_srv.addr().to_string();
    announce_replica(
        &wf_addr,
        &primary.addr().to_string(),
        &primary.partition_ids(),
        Duration::from_secs(5),
    )
    .unwrap();

    // chaos on both planes: the control path mangles chunk boundaries
    // and stalls; the chaotic data path additionally cuts every
    // connection mid-frame after ~150 KB
    let chaos_wf = ChaosTransport::start(
        wf_addr,
        0xC0FFEE,
        ChaosConfig {
            stall_one_in: 64,
            disconnect_after: None,
        },
    );
    let chaos_data = ChaosTransport::start(
        primary.addr().to_string(),
        0xBAD_5EED,
        ChaosConfig {
            stall_one_in: 64,
            disconnect_after: Some(150_000),
        },
    );

    let node_handles: Vec<_> = (0..4)
        .map(|i| {
            let mut cfg = MatchNodeConfig::new(
                chaos_wf.to_string(),
                chaos_data.to_string(),
            );
            // the direct primary is the failover target once the
            // chaotic data path gets cut mid-frame
            cfg.data_addrs.push(primary.addr().to_string());
            cfg.name = format!("chaos-node-{i}");
            cfg.threads = 2;
            cfg.cache_capacity = 4;
            cfg.batch = 4;
            let exec: Arc<dyn TaskExecutor> = Arc::new(RustExecutor::new(
                MatchStrategy::new(StrategyKind::Wam),
            ));
            std::thread::spawn(move || run_match_node(&cfg, exec))
        })
        .collect();

    assert!(
        wf_srv.wait_done(Duration::from_secs(120)),
        "chaos run did not complete"
    );
    let mut reports = Vec::new();
    let mut torn_down = 0;
    for h in node_handles {
        match h.join().expect("node thread") {
            Ok(r) => reports.push(r),
            // a node the injected faults took down entirely: its tasks
            // were re-queued and finished elsewhere
            Err(_) => torn_down += 1,
        }
    }
    let report = wf_srv.finish();
    primary.shutdown();

    // no task lost, none double-completed
    assert_eq!(report.completed_tasks, n_tasks, "every task exactly once");
    assert_eq!(report.total_tasks, n_tasks);
    assert!(report.batch_requests > 0, "batched path exercised");
    assert_eq!(reports.len() + torn_down, 4);
    assert!(!reports.is_empty(), "at least one node must survive");
    // the chaotic data path was really used and really failed over
    let failovers: u64 =
        reports.iter().map(|r| r.replica_failovers).sum();
    assert!(
        failovers >= 1,
        "mid-frame cuts never forced a failover: {reports:?}"
    );

    // and none of it changed the result
    assert_eq!(
        norm_pairs(&report.correspondences),
        norm_pairs(&reference.correspondences),
        "injected faults altered the merged result"
    );
}

/// The api-redesign acceptance test: the fluent builder with the new
/// **sorted-neighborhood strategy behind the `PartitionStrategy`
/// trait** runs end to end on the real TCP engine —
/// `Workflow::for_dataset(..).strategy(SortedNeighborhood{..})
/// .backend(Dist(..)).plan()?.execute()?` — and is result-identical
/// to the same plan on the thread backend.
#[test]
fn builder_sorted_neighborhood_on_dist_matches_threads() {
    use pem::coordinator::Workflow;
    use pem::engine::backend::{Dist, DistOptions, Threads};
    use pem::partition::SortedNeighborhood;

    let data = GeneratorConfig::tiny()
        .with_entities(600)
        .with_seed(42)
        .generate();
    let ce = ComputingEnv::new(2, 2, GIB);

    let threads = Workflow::for_dataset(&data.dataset)
        .strategy(SortedNeighborhood::by_title(80).with_max_size(120))
        .backend(Threads)
        .env(ce)
        .cache(8)
        .run()
        .unwrap();

    let planned = Workflow::for_dataset(&data.dataset)
        .strategy(SortedNeighborhood::by_title(80).with_max_size(120))
        .backend(Dist(DistOptions {
            batch: 2,
            ..DistOptions::default()
        }))
        .env(ce)
        .cache(8)
        .plan()
        .unwrap();
    // the plan is a first-class artifact: inspectable and serializable
    // before any execution
    let skew = planned.plan().skew();
    assert!(skew.n_tasks > 0);
    assert!(skew.max_task_mem > 0, "footprints planned");
    let bytes = planned.plan().to_bytes();
    assert_eq!(
        pem::coordinator::MatchPlan::from_bytes(&bytes)
            .unwrap()
            .to_bytes(),
        bytes
    );
    let dist = planned.execute().unwrap();

    assert_eq!(dist.n_tasks, threads.n_tasks);
    assert_eq!(dist.metrics.tasks, threads.metrics.tasks);
    assert_eq!(dist.metrics.comparisons, threads.metrics.comparisons);
    assert_eq!(dist.result.len(), threads.result.len());
    for c in threads.result.iter() {
        assert_eq!(
            dist.result.similarity(c.e1, c.e2),
            Some(c.sim),
            "pair ({}, {}) differs across backends",
            c.e1,
            c.e2
        );
    }
    assert!(dist.metrics.bytes_fetched > 0, "real socket traffic");
    // the windowed strategy really found duplicates over the wire
    let q = dist.result.quality(&data.truth);
    assert!(q.recall > 0.4, "sn recall {}", q.recall);
}

/// The tentpole acceptance test: a **3-node cluster whose every node
/// rejects every plan task** (one shared §3.1 budget below all of
/// them) completes via scheduler-level task splitting — with the
/// control plane routed through a [`ChaosTransport`] so mid-run
/// `TaskRejected` frames arrive re-chunked and stalled — and the
/// merged result is identical to the thread engine: no sub-task lost,
/// none double-merged.
#[test]
fn dist_runtime_splitting_under_chaos_matches_thread_engine() {
    let data = GeneratorConfig::tiny()
        .with_entities(600)
        .with_seed(42)
        .generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, 60);
    let tasks = generate_tasks(&parts);
    let n_tasks = tasks.len();
    let store = Arc::new(DataService::build(&data.dataset, &parts));

    // reference result from the thread engine (no budgets there)
    let exec = RustExecutor::new(MatchStrategy::new(StrategyKind::Wam));
    let reference = pem::engine::threads::run(
        &ComputingEnv::new(1, 2, GIB),
        &parts,
        tasks.clone(),
        &store,
        &exec,
        pem::engine::threads::ThreadConfig::default(),
    );

    // §3.1 plan metadata, exactly as a MatchPlan would carry it
    let task_mem: std::collections::HashMap<u32, u64> = tasks
        .iter()
        .map(|t| {
            (
                t.id,
                pem::partition::task_memory_bytes(
                    parts.get(t.left).len(),
                    parts.get(t.right).len(),
                    StrategyKind::Wam,
                ),
            )
        })
        .collect();
    let task_sizes: std::collections::HashMap<u32, (u32, u32)> = tasks
        .iter()
        .map(|t| {
            (
                t.id,
                (
                    parts.get(t.left).len() as u32,
                    parts.get(t.right).len() as u32,
                ),
            )
        })
        .collect();
    // below every full task (≥ 20 B · ~60·59/2) but far above one
    // pair: every node must reject every plan task, and splitting
    // must carry the whole run
    let budget = 20_000u64;
    assert!(task_mem.values().all(|&m| m > budget), "test premise");

    let primary =
        DataServiceServer::start(store.clone(), "127.0.0.1:0").unwrap();
    let wf_srv = WorkflowServiceServer::start(
        tasks,
        WorkflowServerConfig {
            policy: Policy::Affinity,
            heartbeat_timeout: Duration::from_secs(3),
            task_mem,
            task_sizes,
            expected_services: 3,
            tracer: None,
            tenancy: None,
        },
        "127.0.0.1:0",
    )
    .unwrap();
    let wf_addr = wf_srv.addr().to_string();
    announce_replica(
        &wf_addr,
        &primary.addr().to_string(),
        &primary.partition_ids(),
        Duration::from_secs(5),
    )
    .unwrap();

    // chaos on the control plane: rejections, spanned assignments and
    // sub-task completions all cross a byte-mangling forwarder
    let chaos_wf = ChaosTransport::start(
        wf_addr,
        0x5117_C0DE,
        ChaosConfig {
            stall_one_in: 64,
            disconnect_after: None,
        },
    );

    let node_handles: Vec<_> = (0..3)
        .map(|i| {
            let mut cfg = MatchNodeConfig::new(
                chaos_wf.to_string(),
                primary.addr().to_string(),
            );
            cfg.name = format!("split-node-{i}");
            cfg.threads = 2;
            cfg.cache_capacity = 4;
            // node 2 keeps the classic per-task pull so both the
            // TaskAssign and the TaskAssignBatch span paths run
            cfg.batch = if i == 2 { 1 } else { 2 };
            cfg.task_memory_budget = Some(budget);
            let exec: Arc<dyn TaskExecutor> = Arc::new(RustExecutor::new(
                MatchStrategy::new(StrategyKind::Wam),
            ));
            std::thread::spawn(move || run_match_node(&cfg, exec))
        })
        .collect();

    assert!(
        wf_srv.wait_done(Duration::from_secs(120)),
        "splitting run did not complete: misfit = {:?}",
        wf_srv.misfit()
    );
    let mut reports = Vec::new();
    for h in node_handles {
        reports.push(h.join().expect("node thread").expect("node report"));
    }
    let report = wf_srv.finish();
    primary.shutdown();

    // every plan task was rejected by all 3 nodes, then split —
    // and still completed exactly once
    assert_eq!(report.completed_tasks, n_tasks, "no task lost");
    assert_eq!(report.total_tasks, n_tasks);
    assert!(report.plan_misfit.is_none());
    assert!(
        report.runtime_splits >= n_tasks as u64,
        "{} splits for {} tasks — every plan task must have split",
        report.runtime_splits,
        n_tasks
    );
    assert!(
        report.oversize_rejections >= 3 * n_tasks as u64,
        "every node must have rejected every plan task"
    );
    let rejected: u64 =
        reports.iter().map(|r| r.tasks_rejected).sum();
    assert_eq!(rejected, report.oversize_rejections);
    for r in &reports {
        assert!(!r.crashed);
        assert!(
            r.tasks_completed > 0,
            "every node executes sub-tasks: {reports:?}"
        );
    }

    // exact pair-space tiling: the comparison total is bit-identical
    // to the unsplit thread run…
    assert_eq!(report.comparisons, reference.metrics.comparisons);
    assert_eq!(report.comparisons, 600 * 599 / 2);
    // …and so is the merged match result
    assert_eq!(
        norm_pairs(&report.correspondences),
        norm_pairs(&reference.correspondences),
        "runtime splitting altered the merged result"
    );
}

/// The fail-fast satellite: two tiny-budget nodes (a single pair
/// already exceeds the budget, so splitting cannot help) make the
/// dist engine fail **immediately** with the typed `PlanMisfit` —
/// never idling until the run timeout.
#[test]
fn dist_unsplittable_plan_fails_fast_with_typed_error() {
    use pem::coordinator::PlanMisfit;
    let data = GeneratorConfig::tiny()
        .with_entities(120)
        .with_seed(3)
        .generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, 40);
    let tasks = generate_tasks(&parts);
    let task_mem: Vec<u64> = tasks
        .iter()
        .map(|t| {
            pem::partition::task_memory_bytes(
                parts.get(t.left).len(),
                parts.get(t.right).len(),
                StrategyKind::Wam,
            )
        })
        .collect();
    let store = Arc::new(DataService::build(&data.dataset, &parts));
    let exec: Arc<dyn TaskExecutor> =
        Arc::new(RustExecutor::new(MatchStrategy::new(StrategyKind::Wam)));

    let started = Instant::now();
    let err = match dist::run(
        &ComputingEnv::new(2, 1, GIB),
        &parts,
        tasks,
        store,
        exec,
        dist::DistConfig {
            task_mem,
            // 10 B is below even one pair's 20 B footprint
            memory_budget: Some(10),
            run_timeout: Duration::from_secs(60),
            ..dist::DistConfig::default()
        },
    ) {
        Ok(_) => panic!("an unsplittable plan must not succeed"),
        Err(e) => e,
    };
    let elapsed = started.elapsed();

    // fail fast: nowhere near the 60 s run timeout
    assert!(
        elapsed < Duration::from_secs(30),
        "fail-fast took {elapsed:?}"
    );
    // and typed: the chain carries the §3.1 misfit with its numbers
    let misfit = err
        .chain()
        .find_map(|e| e.downcast_ref::<PlanMisfit>())
        .unwrap_or_else(|| panic!("no PlanMisfit in chain: {err:#}"));
    assert_eq!(misfit.smallest_budget, 10);
    assert!(misfit.mem_bytes > 10);
    assert!(err.to_string().contains("failed fast"));
}

/// The observability tentpole end to end: the same 3-node
/// chaos + runtime-splitting cluster as above, but with one shared
/// lifecycle [`pem::obs::Tracer`] wired through the workflow server
/// *and* every match node.  Replaying the trace afterwards must
/// reconstruct every plan task's lifecycle **exactly once** — one
/// `Completed` per plan task, every split child merged or re-split
/// exactly once, every `Executed` preceded by an `Assigned` — even
/// though every event was generated on the far side of a
/// byte-mangling control plane.
#[test]
fn dist_chaos_splitting_trace_replays_exactly_once() {
    use pem::obs::Tracer;

    let data = GeneratorConfig::tiny()
        .with_entities(600)
        .with_seed(42)
        .generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, 60);
    let tasks = generate_tasks(&parts);
    let n_tasks = tasks.len();
    let plan_ids: Vec<u32> = tasks.iter().map(|t| t.id).collect();
    let store = Arc::new(DataService::build(&data.dataset, &parts));

    // §3.1 plan metadata so the scheduler can split on rejection
    let task_mem: std::collections::HashMap<u32, u64> = tasks
        .iter()
        .map(|t| {
            (
                t.id,
                pem::partition::task_memory_bytes(
                    parts.get(t.left).len(),
                    parts.get(t.right).len(),
                    StrategyKind::Wam,
                ),
            )
        })
        .collect();
    let task_sizes: std::collections::HashMap<u32, (u32, u32)> = tasks
        .iter()
        .map(|t| {
            (
                t.id,
                (
                    parts.get(t.left).len() as u32,
                    parts.get(t.right).len() as u32,
                ),
            )
        })
        .collect();
    // below every full task: every plan task is rejected and split
    let budget = 20_000u64;
    assert!(task_mem.values().all(|&m| m > budget), "test premise");

    let tracer = Tracer::new(pem::obs::DEFAULT_TRACE_CAPACITY);
    let primary =
        DataServiceServer::start(store.clone(), "127.0.0.1:0").unwrap();
    let wf_srv = WorkflowServiceServer::start(
        tasks,
        WorkflowServerConfig {
            policy: Policy::Affinity,
            heartbeat_timeout: Duration::from_secs(3),
            task_mem,
            task_sizes,
            expected_services: 3,
            tracer: Some(tracer.clone()),
            tenancy: None,
        },
        "127.0.0.1:0",
    )
    .unwrap();
    let wf_addr = wf_srv.addr().to_string();
    announce_replica(
        &wf_addr,
        &primary.addr().to_string(),
        &primary.partition_ids(),
        Duration::from_secs(5),
    )
    .unwrap();

    // lifecycle events are *recorded* cluster-side, but every state
    // transition they witness is driven by frames that crossed this
    // byte-mangling forwarder
    let chaos_wf = ChaosTransport::start(
        wf_addr,
        0x0B5E_55ED,
        ChaosConfig {
            stall_one_in: 64,
            disconnect_after: None,
        },
    );

    let node_handles: Vec<_> = (0..3)
        .map(|i| {
            let mut cfg = MatchNodeConfig::new(
                chaos_wf.to_string(),
                primary.addr().to_string(),
            );
            cfg.name = format!("traced-node-{i}");
            cfg.threads = 2;
            cfg.cache_capacity = 4;
            cfg.batch = if i == 2 { 1 } else { 2 };
            cfg.task_memory_budget = Some(budget);
            cfg.tracer = Some(tracer.clone());
            let exec: Arc<dyn TaskExecutor> = Arc::new(RustExecutor::new(
                MatchStrategy::new(StrategyKind::Wam),
            ));
            std::thread::spawn(move || run_match_node(&cfg, exec))
        })
        .collect();

    assert!(
        wf_srv.wait_done(Duration::from_secs(120)),
        "traced splitting run did not complete"
    );
    for h in node_handles {
        h.join().expect("node thread").expect("node report");
    }
    let report = wf_srv.finish();
    primary.shutdown();

    // the run itself was exact …
    assert_eq!(report.completed_tasks, n_tasks);
    assert_eq!(report.comparisons, 600 * 599 / 2);
    assert!(report.runtime_splits >= n_tasks as u64);

    // … and the trace replays it: the ring dropped nothing, and the
    // replay reconstructs every plan task's lifecycle exactly once
    assert_eq!(tracer.dropped(), 0, "trace ring must not drop events");
    let summary = tracer
        .verify_plan(&plan_ids)
        .expect("chaos trace must replay exactly-once");
    assert_eq!(summary.plan_tasks, n_tasks);
    assert!(
        summary.splits >= n_tasks,
        "{} splits traced for {} plan tasks — every plan task split",
        summary.splits,
        n_tasks
    );
    assert!(summary.subtasks > 0, "split children traced");
    assert!(
        summary.assignments > summary.plan_tasks,
        "sub-task assignments traced through the chaotic control plane"
    );

    // the JSONL dump is the same stream, one line per event
    let dump = tracer.dump_jsonl();
    assert_eq!(dump.lines().count(), tracer.len());
}

/// The v6 live-observability acceptance criterion: `pem stats`
/// semantics against a **running 2-node cluster over real TCP** — an
/// operator connection sends [`Message::StatsRequest`] to the
/// workflow server mid-run, decodes the snapshot from the
/// [`Message::StatsReport`] reply, discovers the data server through
/// the `data_replicas` label exactly as the CLI does, and scrapes
/// that server too.
#[test]
fn dist_live_cluster_stats_scrape_over_tcp() {
    use pem::obs::MetricsSnapshot;
    use pem::rpc::{Message, Transport};

    let data = GeneratorConfig::tiny()
        .with_entities(600)
        .with_seed(42)
        .generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, 60);
    let tasks = generate_tasks(&parts);
    let n_tasks = tasks.len();
    let store = Arc::new(DataService::build(&data.dataset, &parts));

    let primary =
        DataServiceServer::start(store.clone(), "127.0.0.1:0").unwrap();
    let wf_srv = WorkflowServiceServer::start(
        tasks,
        WorkflowServerConfig::default(),
        "127.0.0.1:0",
    )
    .unwrap();
    let wf_addr = wf_srv.addr().to_string();
    announce_replica(
        &wf_addr,
        &primary.addr().to_string(),
        &primary.partition_ids(),
        Duration::from_secs(5),
    )
    .unwrap();

    // small caches keep wire fetches flowing for the whole run
    let node_handles: Vec<_> = (0..2)
        .map(|i| {
            let mut cfg = MatchNodeConfig::new(
                wf_addr.clone(),
                primary.addr().to_string(),
            );
            cfg.name = format!("scraped-node-{i}");
            cfg.threads = 2;
            cfg.cache_capacity = 2;
            let exec: Arc<dyn TaskExecutor> = Arc::new(RustExecutor::new(
                MatchStrategy::new(StrategyKind::Wam),
            ));
            std::thread::spawn(move || run_match_node(&cfg, exec))
        })
        .collect();

    // wait until the run is demonstrably under way, then scrape the
    // workflow server from a fresh operator connection (no Join)
    let deadline = Instant::now() + Duration::from_secs(60);
    while wf_srv.completed() < 1 {
        assert!(Instant::now() < deadline, "run never got going");
        std::thread::sleep(Duration::from_millis(2));
    }
    let mut op =
        Transport::connect(wf_srv.addr(), Duration::from_secs(5)).unwrap();
    let reply = op.request(&Message::StatsRequest).unwrap();
    let Message::StatsReport { stats } = reply else {
        panic!("expected StatsReport, got {}", reply.kind());
    };
    let wf_snap = MetricsSnapshot::from_bytes(&stats).unwrap();
    assert_eq!(wf_snap.label("role"), Some("workflow"));
    assert_eq!(wf_snap.gauge("tasks_total"), Some(n_tasks as u64));
    let done = wf_snap.gauge("tasks_completed").unwrap();
    assert!(
        (1..=n_tasks as u64).contains(&done),
        "mid-run completion count out of range: {done}"
    );

    // follow the replica directory label, exactly as `pem stats` does
    let replicas = wf_snap
        .label("data_replicas")
        .expect("workflow snapshot advertises the data servers")
        .to_string();
    assert_eq!(replicas, primary.addr().to_string());
    let mut dop =
        Transport::connect(replicas.as_str(), Duration::from_secs(5))
            .unwrap();
    let Message::StatsReport { stats } =
        dop.request(&Message::StatsRequest).unwrap()
    else {
        panic!("expected StatsReport from the data server");
    };
    let mid = MetricsSnapshot::from_bytes(&stats).unwrap();
    assert_eq!(mid.label("role"), Some("data-primary"));
    assert_eq!(
        mid.gauge("partitions_held"),
        Some(primary.partition_ids().len() as u64)
    );

    // drain the run, then scrape the data server once more: by now
    // the fetch counters and the latency histogram must both show
    // the traffic the run generated
    assert!(wf_srv.wait_done(Duration::from_secs(60)));
    for h in node_handles {
        h.join().expect("node thread").expect("node report");
    }
    let Message::StatsReport { stats } =
        dop.request(&Message::StatsRequest).unwrap()
    else {
        panic!("expected final StatsReport from the data server");
    };
    let fin = MetricsSnapshot::from_bytes(&stats).unwrap();
    let fetches = fin.counter("fetches_served").unwrap();
    assert!(fetches > 0, "the run must have fetched over TCP");
    let hist = fin.histogram("fetch_serve_ns").unwrap();
    assert_eq!(hist.count, fetches, "one latency sample per fetch");
    assert!(fin.gauge("wire_bytes").unwrap() > 0);

    let report = wf_srv.finish();
    primary.shutdown();
    assert_eq!(report.completed_tasks, n_tasks);
    // the final report's registry agrees with what the wire showed
    assert_eq!(report.stats.gauge("tasks_completed"), Some(n_tasks as u64));
}

/// The pull protocol balances load: with two equal nodes and plenty of
/// tasks, both make progress (no node starves behind the wire).
#[test]
fn dist_pull_scheduling_balances_two_nodes() {
    let data = GeneratorConfig::tiny()
        .with_entities(500)
        .with_seed(11)
        .generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, 50);
    let tasks = generate_tasks(&parts);
    let store = Arc::new(DataService::build(&data.dataset, &parts));
    let exec: Arc<dyn TaskExecutor> =
        Arc::new(RustExecutor::new(MatchStrategy::new(StrategyKind::Wam)));
    let out = dist::run(
        &ComputingEnv::new(2, 2, GIB),
        &parts,
        tasks,
        store,
        exec,
        dist::DistConfig {
            cache_capacity: 8,
            ..dist::DistConfig::default()
        },
    )
    .unwrap();
    assert_eq!(out.node_reports.len(), 2);
    for r in &out.node_reports {
        assert!(
            r.tasks_completed > 0,
            "node {} starved: {:?}",
            r.service,
            out.node_reports
                .iter()
                .map(|n| n.tasks_completed)
                .collect::<Vec<_>>()
        );
    }
    // affinity scheduling engages across the wire
    assert!(out.workflow.affinity_assignments > 0);
}

/// Out-of-core acceptance test (PR 9): a catalog whose encoded payload
/// exceeds `--store-budget` runs a full 2-node distributed match off a
/// [`SpillStore`] — partitions spilled to checksummed files, hot set
/// capped at a few KiB — and produces correspondences identical to the
/// all-resident thread engine on the same seed.  The store counters
/// must prove the cold path was actually exercised: faults > 0 (frames
/// re-materialized from disk) and spill_bytes > 0 (payload lives in
/// spill files, not RAM).
#[test]
fn dist_spill_store_matches_thread_engine() {
    let data = GeneratorConfig::tiny()
        .with_entities(500)
        .with_seed(21)
        .generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, 40);
    let tasks = generate_tasks(&parts);

    // reference: all-resident store through the in-process thread engine
    let resident = Arc::new(DataService::build(&data.dataset, &parts));
    let exec = RustExecutor::new(MatchStrategy::new(StrategyKind::Wam));
    let reference = pem::engine::threads::run(
        &ComputingEnv::new(1, 2, GIB),
        &parts,
        tasks.clone(),
        &resident,
        &exec,
        pem::engine::threads::ThreadConfig::default(),
    );
    let payload: u64 = resident
        .partition_ids()
        .iter()
        .filter_map(|&p| resident.payload_bytes(p))
        .sum();

    // spill-backed store with a hot budget well below the payload, so
    // most reads must fault frames back in from spill files
    let budget = (payload / 8).max(1);
    assert!(
        budget < payload,
        "catalog too small to exceed the store budget"
    );
    let spill = Arc::new(
        DataService::build_with(
            &data.dataset,
            &parts,
            Arc::new(SpillStore::new(budget, None).unwrap()),
        )
        .unwrap(),
    );
    assert_eq!(spill.tier(), "spill");

    let shared_exec: Arc<dyn TaskExecutor> =
        Arc::new(RustExecutor::new(MatchStrategy::new(StrategyKind::Wam)));
    let out = dist::run(
        &ComputingEnv::new(2, 2, GIB),
        &parts,
        tasks,
        spill.clone(),
        shared_exec,
        dist::DistConfig {
            cache_capacity: 4,
            batch: 2,
            ..dist::DistConfig::default()
        },
    )
    .unwrap();

    // byte-identical result to the resident thread-engine run
    assert_eq!(
        norm_pairs(&out.correspondences),
        norm_pairs(&reference.correspondences)
    );
    let dist_result = {
        let mut r = pem::model::MatchResult::new();
        for &c in out.correspondences.iter() {
            r.add(c);
        }
        r
    };
    for c in &reference.correspondences {
        assert_eq!(
            dist_result.similarity(c.e1, c.e2),
            Some(c.sim),
            "similarity drift for {:?}/{:?}",
            c.e1,
            c.e2
        );
    }

    // and the out-of-core path was genuinely on the serving path
    let st = spill.store_stats();
    assert!(st.faults > 0, "no cold faults: {st:?}");
    assert!(st.spill_bytes > 0, "nothing spilled: {st:?}");
    assert!(
        st.hot_bytes <= budget,
        "hot set {} over budget {budget}",
        st.hot_bytes
    );
    assert!(out.data_wire_bytes > 0);
}
