//! Integration tests for the accelerated PJRT path: the AOT artifacts
//! (Layer 1 Pallas kernel + Layer 2 strategy graphs) loaded and executed
//! from Rust, validated against the exact Rust matchers.
//!
//! These tests require `make artifacts`; they are skipped (with a note)
//! when the manifest is absent so `cargo test` stays usable before the
//! first artifact build.

use pem::datagen::GeneratorConfig;
use pem::matching::{MatchStrategy, StrategyKind};
use pem::model::EntityId;
use pem::partition::{partition_size_based, PartitionId};
use pem::runtime::{default_artifact_dir, MatchEngine, PjrtExecutor};
use pem::store::DataService;
use pem::worker::{RustExecutor, TaskExecutor};
use std::sync::Arc;

fn engine_or_skip() -> Option<Arc<MatchEngine>> {
    let dir = default_artifact_dir();
    match MatchEngine::new(&dir) {
        Ok(e) => Some(Arc::new(e)),
        Err(e) => {
            eprintln!("skipping PJRT test: {e:#} (run `make artifacts`)");
            None
        }
    }
}

fn store_with(m: usize, n: usize) -> (crate::Data, DataService) {
    let data = GeneratorConfig::tiny().with_entities(n).generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, m);
    let store = DataService::build(&data.dataset, &parts);
    (data, store)
}

type Data = pem::datagen::GeneratedData;

#[test]
fn manifest_lists_both_strategies() {
    let Some(engine) = engine_or_skip() else { return };
    let m = engine.manifest();
    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        assert!(
            m.pick(kind, 64).is_some(),
            "missing small artifact for {}",
            kind.name()
        );
        assert!(
            m.pick(kind, 1000).is_some(),
            "missing paper-size artifact for {}",
            kind.name()
        );
    }
}

#[test]
fn pjrt_runs_and_scores_in_range() {
    let Some(engine) = engine_or_skip() else { return };
    let (_, store) = store_with(50, 100);
    let left = store.fetch(PartitionId(0)).unwrap();
    let right = store.fetch(PartitionId(1)).unwrap();
    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        let params = MatchStrategy::new(kind).params.values;
        let (sims, cap) = engine
            .run_pair(kind, params, &left, &right)
            .expect("run_pair");
        assert_eq!(sims.len(), cap * cap);
        assert!(cap >= 50);
        for &s in &sims {
            assert!((0.0..=1.0 + 1e-5).contains(&s), "score {s}");
        }
        // padded region must be exactly zero
        for i in left.len()..cap {
            for j in 0..cap {
                assert_eq!(sims[i * cap + j], 0.0, "padding row {i}");
            }
        }
    }
}

#[test]
fn pjrt_scores_correlate_with_rust_matchers() {
    // The accelerated path substitutes hashed-q-gram matchers (and a
    // q-gram proxy for edit distance) for the exact ones, so individual
    // borderline decisions may flip.  The substitution claim (DESIGN.md
    // §Hardware-Adaptation) is: scores correlate strongly, and every
    // *confident* exact-path match is found by the accelerated path.
    let Some(engine) = engine_or_skip() else { return };
    let (_, store) = store_with(100, 100);
    let p = store.fetch(PartitionId(0)).unwrap();
    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        let strategy = MatchStrategy::new(kind);
        // continuous scores: for WAM pass margin=1.0 so the in-graph
        // threshold-discard keeps every pair (otherwise both paths emit
        // 0 for most pairs and correlation is undefined)
        let cont_params = match kind {
            StrategyKind::Wam => [0.5, 0.5, 0.75, 1.0],
            StrategyKind::Lrm => strategy.params.values,
        };
        let (sims, cap) = engine
            .run_pair(kind, cont_params, &p, &p)
            .expect("run_pair");
        let feats = &p.features;
        let mut xs = Vec::new(); // exact continuous combination
        let mut ys = Vec::new(); // accelerated continuous score
        for i in 0..p.len() {
            for j in (i + 1)..p.len() {
                let s = pem::matching::MatcherScores::all(
                    &feats[i], &feats[j],
                );
                let exact = match kind {
                    StrategyKind::Wam => {
                        0.5 * s.edit_title + 0.5 * s.trigram_desc
                    }
                    StrategyKind::Lrm => strategy.combine(&s),
                };
                xs.push(exact);
                ys.push(sims[i * cap + j] as f64);
            }
        }
        let r = pem::util::stats::pearson(&xs, &ys);
        assert!(
            r > 0.75,
            "{}: continuous score correlation {r} over {} pairs",
            kind.name(),
            xs.len()
        );

        // decision containment with the real (discarding) params: every
        // confident exact match must be found by the accelerated path
        let (dsims, dcap) = engine
            .run_pair(kind, strategy.params.values, &p, &p)
            .expect("run_pair");
        let mut confident_found = 0;
        let mut confident_total = 0;
        for i in 0..p.len() {
            for j in (i + 1)..p.len() {
                let exact = strategy.similarity(&feats[i], &feats[j]);
                if exact >= strategy.threshold + 0.1 {
                    confident_total += 1;
                    confident_found += (dsims[i * dcap + j] as f64
                        >= strategy.threshold)
                        as usize;
                }
            }
        }
        if confident_total > 0 {
            assert!(
                confident_found * 10 >= confident_total * 9,
                "{}: accelerated path missed confident matches: {}/{}",
                kind.name(),
                confident_found,
                confident_total
            );
        }
    }
}

#[test]
fn pjrt_intra_task_finds_duplicates() {
    let Some(engine) = engine_or_skip() else { return };
    let (data, store) = store_with(120, 120);
    let p = store.fetch(PartitionId(0)).unwrap();
    let strategy = MatchStrategy::new(StrategyKind::Wam);
    let pjrt = PjrtExecutor::new(engine, strategy);
    let found = pjrt.execute(&p, &p, true);
    let set: std::collections::HashSet<_> =
        found.iter().map(|c| c.pair()).collect();
    let hits = data
        .truth
        .iter()
        .filter(|&&(a, b)| set.contains(&(a, b)))
        .count();
    assert!(
        hits * 10 >= data.truth.len() * 7,
        "accelerated recall {hits}/{}",
        data.truth.len()
    );
    // intra task yields no self pairs and no (j, i) duplicates
    for c in &found {
        assert!(c.e1 < c.e2);
    }
}

#[test]
fn pjrt_capacity_selection_pads_correctly() {
    let Some(engine) = engine_or_skip() else { return };
    // 130 entities forces the 256-capacity artifact
    let (_, store) = store_with(130, 130);
    let p = store.fetch(PartitionId(0)).unwrap();
    let params = MatchStrategy::new(StrategyKind::Wam).params.values;
    let (sims, cap) = engine
        .run_pair(StrategyKind::Wam, params, &p, &p)
        .expect("run_pair");
    assert!(cap >= 130, "cap {cap}");
    assert_eq!(sims.len(), cap * cap);
}
