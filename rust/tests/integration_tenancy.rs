//! End-to-end tests of the multi-tenant resident match service
//! (protocol v7): several clients submit serialized match plans over
//! real TCP to one long-lived cluster, which admission-checks them
//! against the aggregate §3.1 node budgets, fair-schedules their
//! tasks side by side, and streams each tenant's result back on an
//! isolated channel.
//!
//! Three scenarios:
//!
//! * three *concurrent* plans built with different partitioning
//!   strategies on a 3-node cluster — each result byte-identical to a
//!   solo thread-engine run of the same plan;
//! * admission control: an over-budget plan is refused in one round
//!   trip with the typed required/available verdict, and the *same
//!   bytes* are admitted after a roomier node joins;
//! * tenant isolation under chaos: two tenants submit through a
//!   byte-mangling [`ChaosTransport`]; one client's connection is cut
//!   mid-run, its plan is aborted server-side, and the survivor's
//!   result is still byte-identical — then the cluster accepts and
//!   completes a third plan, proving the abort left it healthy.

use pem::blocking::BlockingMethod;
use pem::cluster::ComputingEnv;
use pem::coordinator::MatchPlan;
use pem::datagen::GeneratorConfig;
use pem::engine::dist;
use pem::matching::{MatchStrategy, StrategyKind};
use pem::model::{Correspondence, Dataset, EntityId};
use pem::partition::{
    partition_size_based, BlockingBased, PartitionStrategy, SizeBased,
    SortedNeighborhood,
};
use pem::rpc::{Message, Transport};
use pem::service::{
    run_match_node, MatchNodeConfig, TENANT_ABORTED, TENANT_DONE,
};
use pem::store::DataService;
use pem::util::GIB;
use pem::worker::{RustExecutor, TaskExecutor};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------- chaos
// A lean copy of `integration_dist.rs`'s byte-mangling forwarder
// (test binaries cannot share code without a support crate): client
// frames are re-chunked down to single bytes, optionally stalled, and
// optionally cut after a byte budget — the resident control plane
// must survive the mangling and treat the cut as a client death.

/// Fault profile of one [`ChaosTransport`] direction.
#[derive(Clone, Copy)]
struct ChaosConfig {
    /// 1-in-N chance to stall 1–20 ms before forwarding a chunk
    /// (0 = never stall).
    stall_one_in: usize,
    /// Cut the connection (both directions, mid-frame with
    /// overwhelming probability) after forwarding this many bytes.
    disconnect_after: Option<u64>,
}

struct ChaosTransport;

impl ChaosTransport {
    /// Start a forwarder to `upstream`; returns the address clients
    /// should connect to.
    fn start(
        upstream: String,
        seed: u64,
        cfg: ChaosConfig,
    ) -> std::net::SocketAddr {
        let listener =
            std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let mut conn_seq = 0u64;
            for client in listener.incoming() {
                let Ok(client) = client else { break };
                conn_seq += 1;
                let Ok(server) =
                    std::net::TcpStream::connect(&upstream)
                else {
                    continue;
                };
                let c2 = client.try_clone().unwrap();
                let s2 = server.try_clone().unwrap();
                let conn_seed = seed
                    ^ conn_seq.wrapping_mul(0x9e37_79b9_7f4a_7c15);
                std::thread::spawn(move || {
                    chaos_pump(
                        client,
                        s2,
                        pem::util::Rng::new(conn_seed),
                        cfg,
                    )
                });
                std::thread::spawn(move || {
                    chaos_pump(
                        server,
                        c2,
                        pem::util::Rng::new(conn_seed ^ 0xFF),
                        cfg,
                    )
                });
            }
        });
        addr
    }
}

fn chaos_pump(
    mut from: std::net::TcpStream,
    mut to: std::net::TcpStream,
    mut rng: pem::util::Rng,
    cfg: ChaosConfig,
) {
    use std::io::{Read, Write};
    let mut buf = [0u8; 4096];
    let mut forwarded = 0u64;
    'pump: loop {
        let max = if rng.gen_bool(0.3) {
            1 + rng.gen_range(7)
        } else {
            1 + rng.gen_range(buf.len() - 1)
        };
        let n = match from.read(&mut buf[..max]) {
            Ok(0) | Err(_) => break,
            Ok(n) => n,
        };
        if cfg.stall_one_in > 0 && rng.gen_range(cfg.stall_one_in) == 0 {
            std::thread::sleep(Duration::from_millis(
                (1 + rng.gen_range(19)) as u64,
            ));
        }
        let mut off = 0;
        while off < n {
            let chunk = 1 + rng.gen_range(n - off);
            if to.write_all(&buf[off..off + chunk]).is_err() {
                break 'pump;
            }
            off += chunk;
        }
        forwarded += n as u64;
        if let Some(limit) = cfg.disconnect_after {
            if forwarded >= limit {
                break;
            }
        }
    }
    let _ = from.shutdown(std::net::Shutdown::Both);
    let _ = to.shutdown(std::net::Shutdown::Both);
}

// -------------------------------------------------------------- helpers

/// Order-normalize a correspondence set for exact comparison.
fn norm_pairs(cs: &[Correspondence]) -> Vec<(EntityId, EntityId)> {
    let mut r = pem::model::MatchResult::new();
    for &c in cs {
        r.add(c);
    }
    let mut pairs: Vec<(EntityId, EntityId)> =
        r.iter().map(|c| c.pair()).collect();
    pairs.sort_unstable();
    pairs
}

fn wam_exec() -> Arc<dyn TaskExecutor> {
    Arc::new(RustExecutor::new(MatchStrategy::new(StrategyKind::Wam)))
}

/// Build a submittable plan for `dataset` with the given partitioning
/// strategy (always WAM — the resident cluster's node executors are
/// fixed at start).
fn plan_for(
    dataset: &Dataset,
    strategy: &dyn PartitionStrategy,
) -> MatchPlan {
    MatchPlan::build(
        dataset,
        strategy,
        StrategyKind::Wam,
        &ComputingEnv::new(1, 1, GIB),
    )
    .unwrap()
}

/// Solo reference run of `plan` through the in-process thread engine
/// — the byte-identical oracle every tenant result is held to.
fn thread_reference(
    dataset: &Dataset,
    plan: &MatchPlan,
) -> Vec<(EntityId, EntityId)> {
    let store = DataService::build(dataset, &plan.partitions);
    let exec = RustExecutor::new(MatchStrategy::new(StrategyKind::Wam));
    let out = pem::engine::threads::run(
        &ComputingEnv::new(1, 2, GIB),
        &plan.partitions,
        plan.tasks.clone(),
        &store,
        &exec,
        pem::engine::threads::ThreadConfig::default(),
    );
    norm_pairs(&out.correspondences)
}

/// Terminal outcome of one submitted plan as a client observed it.
struct PlanOutcome {
    plan: u32,
    state: u8,
    comparisons: u64,
    matches: Vec<Correspondence>,
    detail: String,
}

/// Submit `plan_bytes` on `t` and poll every `poll` until terminal.
fn submit_and_follow(
    t: &mut Transport,
    name: &str,
    plan_bytes: Vec<u8>,
    poll: Duration,
) -> PlanOutcome {
    let plan = match t
        .request(&Message::PlanSubmit {
            name: name.to_string(),
            plan: plan_bytes,
        })
        .unwrap()
    {
        Message::PlanAccepted { plan } => plan,
        other => panic!("submit of {name:?} refused: {other:?}"),
    };
    follow(t, plan, poll)
}

/// Poll `plan` on `t` until it reaches a terminal state.
fn follow(t: &mut Transport, plan: u32, poll: Duration) -> PlanOutcome {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        assert!(
            Instant::now() < deadline,
            "plan #{plan} never reached a terminal state"
        );
        match t.request(&Message::PlanStatus { plan }).unwrap() {
            Message::PlanStatusReport { .. } => {
                std::thread::sleep(poll)
            }
            Message::PlanResult {
                plan,
                state,
                comparisons,
                matches,
                detail,
            } => {
                return PlanOutcome {
                    plan,
                    state,
                    comparisons,
                    matches,
                    detail,
                }
            }
            other => panic!("unexpected status reply: {other:?}"),
        }
    }
}

fn connect(addr: std::net::SocketAddr) -> Transport {
    Transport::connect(addr, Duration::from_secs(5)).unwrap()
}

/// Start a resident cluster on `dataset` with a seed store holding
/// size-based partitions (the tenants bring their own partitions; the
/// seed ones only exercise the renumbering offset).
fn resident_cluster(
    dataset: &Arc<Dataset>,
    nodes: usize,
    cfg: dist::DistConfig,
) -> dist::ResidentCluster {
    let ids: Vec<EntityId> =
        dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, 100);
    let store = Arc::new(DataService::build(dataset, &parts));
    dist::serve_resident(
        &ComputingEnv::new(nodes, 2, GIB),
        dataset.clone(),
        store,
        wam_exec(),
        cfg,
    )
    .unwrap()
}

// ---------------------------------------------------------------- tests

/// The tentpole acceptance test: three clients concurrently submit
/// plans built with three different partitioning strategies to one
/// 3-node resident cluster.  Every plan completes, and each tenant's
/// isolated result is byte-identical to a solo thread-engine run of
/// the same plan — interleaved fair scheduling must change *nothing*
/// about any tenant's output.
#[test]
fn three_concurrent_mixed_strategy_plans_are_byte_identical() {
    let data = GeneratorConfig::tiny()
        .with_entities(900)
        .with_seed(77)
        .generate();
    let dataset = Arc::new(data.dataset);

    let strategies: Vec<(&str, Box<dyn PartitionStrategy>)> = vec![
        ("size", Box::new(SizeBased { max_size: Some(60) })),
        (
            "blocking",
            Box::new(BlockingBased {
                method: BlockingMethod::product_type(),
                max_size: Some(120),
                min_size: Some(20),
            }),
        ),
        (
            "sorted-neighborhood",
            Box::new(SortedNeighborhood {
                attribute: pem::model::ATTR_TITLE.to_string(),
                window: 60,
                max_size: None,
            }),
        ),
    ];
    let plans: Vec<(String, MatchPlan, Vec<(EntityId, EntityId)>)> =
        strategies
            .iter()
            .map(|(name, s)| {
                let plan = plan_for(&dataset, s.as_ref());
                assert!(!plan.tasks.is_empty(), "{name}: empty plan");
                let reference = thread_reference(&dataset, &plan);
                (name.to_string(), plan, reference)
            })
            .collect();

    let cluster = resident_cluster(
        &dataset,
        3,
        dist::DistConfig {
            cache_capacity: 8,
            // the fairness quota: no tenant may hold more than 2
            // assignments at once, so all three interleave
            per_tenant_inflight: Some(2),
            ..dist::DistConfig::default()
        },
    );
    let wf_addr = cluster.workflow_addr();

    // three concurrent submitting clients, one per plan
    let handles: Vec<_> = plans
        .iter()
        .map(|(name, plan, _)| {
            let name = name.clone();
            let bytes = plan.to_bytes();
            std::thread::spawn(move || {
                let mut t = connect(wf_addr);
                submit_and_follow(
                    &mut t,
                    &name,
                    bytes,
                    Duration::from_millis(5),
                )
            })
        })
        .collect();
    let outcomes: Vec<PlanOutcome> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    // distinct plan ids were handed out
    let mut ids: Vec<u32> = outcomes.iter().map(|o| o.plan).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 3, "plan ids must be unique");

    for (o, (name, plan, reference)) in outcomes.iter().zip(&plans) {
        assert_eq!(
            o.state, TENANT_DONE,
            "plan {name:?} (#{}) not done: {}",
            o.plan, o.detail
        );
        assert!(o.comparisons > 0, "{name}: no comparisons");
        assert_eq!(
            &norm_pairs(&o.matches),
            reference,
            "plan {name:?} (#{}, {} tasks) diverged from its solo \
             thread-engine run",
            o.plan,
            plan.tasks.len()
        );
    }
    // the size-based plan covers the full cross product exactly once
    let size = &outcomes[0];
    assert_eq!(size.comparisons, 900 * 899 / 2);

    let report = cluster.shutdown();
    // all three tenants' tasks flowed through the one scheduler
    let total: usize =
        plans.iter().map(|(_, p, _)| p.tasks.len()).sum();
    assert!(
        report.completed_tasks >= total,
        "{} tasks completed for {} submitted",
        report.completed_tasks,
        total
    );
}

/// Admission control end to end: a cluster whose only node joined
/// with a 1-byte §3.1 budget refuses a plan in ONE round trip with
/// the typed required/available verdict (no queue-and-time-out); the
/// *same plan bytes* are admitted after an unlimited node joins, and
/// the plan then runs to a byte-identical result.
#[test]
fn over_budget_plan_denied_fast_then_admitted_after_roomy_join() {
    let data = GeneratorConfig::tiny()
        .with_entities(300)
        .with_seed(5)
        .generate();
    let dataset = Arc::new(data.dataset);
    let plan =
        plan_for(&dataset, &SizeBased { max_size: Some(50) });
    let required: u64 =
        plan.task_mem.iter().fold(0, |a, &m| a.saturating_add(m));
    assert!(required > 1, "test premise: the plan needs memory");
    let reference = thread_reference(&dataset, &plan);

    let cluster = resident_cluster(
        &dataset,
        1,
        dist::DistConfig {
            // the lone node joins with a 1-byte budget: aggregate
            // cluster budget = 1
            memory_budget: Some(1),
            ..dist::DistConfig::default()
        },
    );
    let wf_addr = cluster.workflow_addr();
    // let the node join before probing the aggregate budget
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut t = connect(wf_addr);
    let verdict = loop {
        assert!(Instant::now() < deadline, "node never joined");
        let started = Instant::now();
        match t
            .request(&Message::PlanSubmit {
                name: "too-big".into(),
                plan: plan.to_bytes(),
            })
            .unwrap()
        {
            Message::PlanRejected {
                available: 0, ..
            } => {
                // the node has not joined yet (aggregate budget 0);
                // retry until its 1-byte budget is on the books
                std::thread::sleep(Duration::from_millis(20));
            }
            Message::PlanRejected {
                required,
                available,
                reason,
            } => {
                // the acceptance criterion: the denial is immediate,
                // not a run_timeout
                assert!(
                    started.elapsed() < Duration::from_secs(5),
                    "denial took {:?}",
                    started.elapsed()
                );
                break (required, available, reason);
            }
            other => panic!("expected a denial, got {other:?}"),
        }
    };
    assert_eq!(verdict.0, required, "denial must quote the footprint");
    assert_eq!(verdict.1, 1, "denial must quote the live budget");
    assert!(
        verdict.2.contains("admission denied"),
        "unclear denial: {}",
        verdict.2
    );

    // a roomier node joins (budget 0 on the wire = unlimited) …
    let node_addr = cluster.workflow_addr().to_string();
    let data_addr = cluster.data_addr().to_string();
    let roomy = std::thread::spawn(move || {
        let mut cfg = MatchNodeConfig::new(node_addr, data_addr);
        cfg.name = "roomy".into();
        cfg.threads = 2;
        run_match_node(&cfg, wam_exec())
    });

    // … and the very same bytes are now admitted and run to the
    // byte-identical result (retry while the join is in flight)
    let deadline = Instant::now() + Duration::from_secs(10);
    let outcome = loop {
        assert!(Instant::now() < deadline, "plan never admitted");
        match t
            .request(&Message::PlanSubmit {
                name: "fits-now".into(),
                plan: plan.to_bytes(),
            })
            .unwrap()
        {
            Message::PlanAccepted { plan } => {
                break follow(&mut t, plan, Duration::from_millis(5))
            }
            Message::PlanRejected { .. } => {
                std::thread::sleep(Duration::from_millis(20))
            }
            other => panic!("unexpected reply: {other:?}"),
        }
    };
    assert_eq!(outcome.state, TENANT_DONE, "{}", outcome.detail);
    assert_eq!(norm_pairs(&outcome.matches), reference);

    cluster.shutdown();
    let _ = roomy.join();
}

/// Tenant isolation under chaos: two tenants submit through
/// byte-mangling proxies; tenant 1's client connection is cut
/// mid-run.  The server must abort plan 1 (drain its tasks), leave
/// tenant 2's result byte-identical to its solo run, and stay healthy
/// enough to admit and complete a third plan afterwards.
#[test]
fn client_cut_mid_run_aborts_its_plan_and_spares_the_survivor() {
    let data = GeneratorConfig::tiny()
        .with_entities(1200)
        .with_seed(23)
        .generate();
    let dataset = Arc::new(data.dataset);
    // plan 1 is deliberately long-running (many tiny tasks) so the
    // cut below lands while it is still in flight
    let victim_plan =
        plan_for(&dataset, &SizeBased { max_size: Some(15) });
    assert!(victim_plan.tasks.len() > 1000, "needs a long runway");
    let survivor_plan = plan_for(
        &dataset,
        &BlockingBased {
            method: BlockingMethod::product_type(),
            max_size: Some(150),
            min_size: Some(30),
        },
    );
    let survivor_reference = thread_reference(&dataset, &survivor_plan);

    let cluster =
        resident_cluster(&dataset, 2, dist::DistConfig::default());
    let wf_addr = cluster.workflow_addr();

    // the victim's proxy cuts shortly after the submit frame passed;
    // the survivor's proxy only stalls and re-chunks
    let victim_bytes = victim_plan.to_bytes();
    let victim_proxy = ChaosTransport::start(
        wf_addr.to_string(),
        0xC0FFEE,
        ChaosConfig {
            stall_one_in: 0,
            disconnect_after: Some(victim_bytes.len() as u64 + 256),
        },
    );
    let survivor_proxy = ChaosTransport::start(
        wf_addr.to_string(),
        0xDECAF,
        ChaosConfig {
            stall_one_in: 6,
            disconnect_after: None,
        },
    );

    let victim_id: Arc<Mutex<Option<u32>>> =
        Arc::new(Mutex::new(None));
    let victim_slot = victim_id.clone();
    let victim = std::thread::spawn(move || {
        let mut t = connect(victim_proxy);
        let plan = match t
            .request(&Message::PlanSubmit {
                name: "victim".into(),
                plan: victim_bytes,
            })
            .unwrap()
        {
            Message::PlanAccepted { plan } => plan,
            other => panic!("victim submit refused: {other:?}"),
        };
        *victim_slot.lock().unwrap() = Some(plan);
        // poll until the chaos proxy cuts the connection out from
        // under us — the request error IS the expected outcome
        loop {
            match t.request(&Message::PlanStatus { plan }) {
                Ok(Message::PlanStatusReport { .. }) => {
                    std::thread::sleep(Duration::from_millis(5))
                }
                Ok(Message::PlanResult { state, .. }) => {
                    panic!(
                        "plan finished (state {state}) before the \
                         cut — grow the victim plan"
                    )
                }
                Ok(other) => panic!("unexpected: {other:?}"),
                Err(_) => break, // the cut
            }
        }
    });
    let survivor_bytes = survivor_plan.to_bytes();
    let survivor = std::thread::spawn(move || {
        let mut t = connect(survivor_proxy);
        submit_and_follow(
            &mut t,
            "survivor",
            survivor_bytes,
            Duration::from_millis(10),
        )
    });

    victim.join().unwrap();
    let victim_plan_id =
        victim_id.lock().unwrap().expect("victim was admitted");

    // observer on a clean connection: the cut client's plan must be
    // terminal-aborted (the on_close hook drains its open tasks)
    let mut obs = connect(wf_addr);
    let aborted =
        follow(&mut obs, victim_plan_id, Duration::from_millis(10));
    assert_eq!(
        aborted.state, TENANT_ABORTED,
        "victim plan ended as {} ({})",
        aborted.state, aborted.detail
    );
    assert!(
        aborted.detail.contains("aborted"),
        "unclear abort detail: {}",
        aborted.detail
    );
    // re-polling a terminal plan is idempotent
    let again =
        follow(&mut obs, victim_plan_id, Duration::from_millis(10));
    assert_eq!(again.state, TENANT_ABORTED);

    // the surviving tenant is untouched: byte-identical result
    let outcome = survivor.join().unwrap();
    assert_eq!(outcome.state, TENANT_DONE, "{}", outcome.detail);
    assert_eq!(
        norm_pairs(&outcome.matches),
        survivor_reference,
        "the abort leaked into the survivor's result"
    );

    // and the cluster is still serving: a third plan completes
    let third = plan_for(&dataset, &SizeBased { max_size: Some(200) });
    let third_reference = thread_reference(&dataset, &third);
    let after = submit_and_follow(
        &mut obs,
        "after-the-abort",
        third.to_bytes(),
        Duration::from_millis(10),
    );
    assert_eq!(after.state, TENANT_DONE, "{}", after.detail);
    assert_eq!(norm_pairs(&after.matches), third_reference);

    cluster.shutdown();
}
