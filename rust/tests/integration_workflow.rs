//! Cross-module integration tests: full workflows over the public API.

use pem::blocking::BlockingMethod;
use pem::cluster::ComputingEnv;
use pem::coordinator::workflow::EngineChoice;
use pem::coordinator::{
    run_workflow, PartitioningChoice, Policy, WorkflowConfig,
};
use pem::datagen::GeneratorConfig;
use pem::matching::StrategyKind;
use pem::util::GIB;

fn small_ce() -> ComputingEnv {
    ComputingEnv::new(1, 2, GIB)
}

fn blocking_cfg(kind: StrategyKind, max: usize, min: usize) -> WorkflowConfig {
    let mut cfg = WorkflowConfig::blocking_based(kind);
    if let PartitioningChoice::BlockingBased {
        max_size, min_size, ..
    } = &mut cfg.partitioning
    {
        *max_size = Some(max);
        *min_size = min;
    }
    cfg
}

#[test]
fn size_vs_blocking_same_truth_recall() {
    let data = GeneratorConfig::tiny().with_entities(900).generate();
    let ce = small_ce();
    let size = run_workflow(
        &data,
        &WorkflowConfig::size_based(StrategyKind::Wam)
            .with_engine(EngineChoice::Threads),
        &ce,
    )
    .unwrap();
    let block = run_workflow(
        &data,
        &blocking_cfg(StrategyKind::Wam, 150, 30)
            .with_engine(EngineChoice::Threads),
        &ce,
    )
    .unwrap();
    // blocking must preserve nearly every duplicate the Cartesian run
    // found (same-block + misc routing), at far fewer comparisons
    let qs = size.result.quality(&data.truth);
    let qb = block.result.quality(&data.truth);
    assert!(qb.recall >= qs.recall - 0.02, "{} vs {}", qb.recall, qs.recall);
    assert!(block.metrics.comparisons < size.metrics.comparisons);
}

#[test]
fn all_blocking_methods_complete_and_find_duplicates() {
    let data = GeneratorConfig::tiny().with_entities(600).generate();
    let ce = small_ce();
    for method in [
        BlockingMethod::product_type(),
        BlockingMethod::manufacturer(),
        BlockingMethod::SortedNeighborhood {
            attribute: pem::model::ATTR_TITLE.to_string(),
            window: 80,
        },
        BlockingMethod::Canopy {
            loose: 0.35,
            tight: 0.75,
        },
    ] {
        let mut cfg = blocking_cfg(StrategyKind::Wam, 150, 30)
            .with_engine(EngineChoice::Threads);
        if let PartitioningChoice::BlockingBased { method: m, .. } =
            &mut cfg.partitioning
        {
            *m = method.clone();
        }
        let out = run_workflow(&data, &cfg, &ce).unwrap();
        let q = out.result.quality(&data.truth);
        assert!(
            q.recall > 0.4,
            "method {method:?} recall {}",
            q.recall
        );
    }
}

#[test]
fn cache_and_policy_do_not_change_results() {
    let data = GeneratorConfig::tiny().with_entities(500).generate();
    let ce = ComputingEnv::new(2, 2, GIB);
    let mut reference: Option<usize> = None;
    for cache in [0usize, 4, 64] {
        for policy in [Policy::Fifo, Policy::Affinity] {
            let mut cfg = blocking_cfg(StrategyKind::Lrm, 120, 20)
                .with_engine(EngineChoice::Threads)
                .with_cache(cache);
            cfg.policy = policy;
            let out = run_workflow(&data, &cfg, &ce).unwrap();
            match reference {
                None => reference = Some(out.result.len()),
                Some(r) => assert_eq!(
                    out.result.len(),
                    r,
                    "cache={cache} policy={policy:?}"
                ),
            }
        }
    }
}

#[test]
fn simulator_speedup_shape_matches_paper() {
    // the central claim: near-linear speedup to 16 cores for both
    // partitioning strategies
    let data = GeneratorConfig::tiny().with_entities(2500).generate();
    for cfg in [
        WorkflowConfig::size_based(StrategyKind::Wam),
        blocking_cfg(StrategyKind::Wam, 200, 40),
    ] {
        let mut cfg = cfg;
        cfg.calibrate = false;
        if let PartitioningChoice::SizeBased { max_size } =
            &mut cfg.partitioning
        {
            *max_size = Some(200);
        }
        let mut times = Vec::new();
        for cores in [1usize, 4, 16] {
            let nodes = cores.div_ceil(4).max(1);
            let ce =
                ComputingEnv::new(nodes, cores.div_ceil(nodes), 3 * GIB);
            let out = run_workflow(&data, &cfg, &ce).unwrap();
            times.push(out.metrics.makespan_ns);
        }
        let s4 = times[0] as f64 / times[1] as f64;
        let s16 = times[0] as f64 / times[2] as f64;
        assert!(s4 > 2.8, "speedup@4 {s4}");
        assert!(s16 > 8.0, "speedup@16 {s16}");
        assert!(s16 < 16.5, "speedup@16 {s16} super-linear?");
    }
}

#[test]
fn caching_improves_simulated_time_with_high_hit_ratio() {
    let data = GeneratorConfig::tiny().with_entities(3000).generate();
    let mut base = blocking_cfg(StrategyKind::Wam, 150, 30);
    base.calibrate = false;
    let ce = ComputingEnv::new(4, 4, 3 * GIB);
    let nc = run_workflow(&data, &base.clone().with_cache(0), &ce).unwrap();
    let c = run_workflow(&data, &base.with_cache(16), &ce).unwrap();
    assert!(c.metrics.makespan_ns < nc.metrics.makespan_ns);
    assert!(
        c.metrics.hit_ratio() > 0.5,
        "hr {}",
        c.metrics.hit_ratio()
    );
}

#[test]
fn wam_faster_than_lrm_in_simulation() {
    if cfg!(debug_assertions) {
        // calibration measures this build's real matcher costs; the
        // WAM < LRM relation is a property of the optimized build (see
        // engine::calibrate::tests::lrm_costs_more_than_wam)
        return;
    }
    let data = GeneratorConfig::tiny().with_entities(2000).generate();
    let ce = ComputingEnv::new(1, 4, 3 * GIB);
    // calibrated: uses real per-pair costs of both strategies
    let wam =
        run_workflow(&data, &blocking_cfg(StrategyKind::Wam, 200, 40), &ce)
            .unwrap();
    let lrm =
        run_workflow(&data, &blocking_cfg(StrategyKind::Lrm, 100, 20), &ce)
            .unwrap();
    assert!(
        wam.metrics.makespan_ns < lrm.metrics.makespan_ns,
        "wam {} vs lrm {}",
        wam.metrics.makespan_ns,
        lrm.metrics.makespan_ns
    );
    // LRM has more tasks due to its smaller max partition size (Fig 9)
    assert!(lrm.n_tasks > wam.n_tasks);
}

#[test]
fn misc_entities_still_matchable() {
    // entities with missing product type must still find their duplicates
    // through the misc routing
    let data = GeneratorConfig {
        n_entities: 800,
        missing_product_type: 0.4, // heavy misc share
        ..GeneratorConfig::default()
    }
    .generate();
    let out = run_workflow(
        &data,
        &blocking_cfg(StrategyKind::Wam, 150, 30)
            .with_engine(EngineChoice::Threads),
        &small_ce(),
    )
    .unwrap();
    let q = out.result.quality(&data.truth);
    assert!(q.recall > 0.75, "recall {} with 40% misc", q.recall);
    assert!(out.n_misc_partitions >= 1);
}

/// The new sorted-neighborhood strategy crosses engines like the
/// legacy ones: executing its window/overlap tasks inside the
/// simulator yields exactly the thread engine's correspondences.
#[test]
fn sorted_neighborhood_sim_execute_equals_threads_result() {
    use pem::coordinator::Workflow;
    use pem::engine::backend::{Sim, SimOptions, Threads};
    use pem::partition::SortedNeighborhood;

    let data = GeneratorConfig::tiny()
        .with_entities(600)
        .with_seed(9)
        .generate();
    let sn = || SortedNeighborhood::by_title(60).with_max_size(120);
    let t = Workflow::for_dataset(&data.dataset)
        .strategy(sn())
        .backend(Threads)
        .env(small_ce())
        .run()
        .unwrap();
    let s = Workflow::for_dataset(&data.dataset)
        .strategy(sn())
        .backend(Sim(SimOptions {
            execute: true,
            calibrate: false,
            ..SimOptions::default()
        }))
        .env(ComputingEnv::paper_testbed(2))
        .run()
        .unwrap();
    assert_eq!(t.n_tasks, s.n_tasks);
    assert_eq!(t.metrics.comparisons, s.metrics.comparisons);
    assert_eq!(t.result.len(), s.result.len());
    for c in t.result.iter() {
        assert!(s.result.contains(c.e1, c.e2));
    }
}
