//! Plan determinism + legacy-shim equivalence (the api_redesign
//! acceptance tests).
//!
//! 1. A [`MatchPlan`] built twice from the same dataset / strategy /
//!    environment serializes to byte-identical output, for every
//!    strategy behind the trait (property test over seeds).
//! 2. The new builder path is result-identical to the legacy
//!    `WorkflowConfig` path for both legacy strategies — the shim is a
//!    pure translation, not a second implementation.

use pem::cluster::ComputingEnv;
use pem::coordinator::workflow::EngineChoice;
use pem::coordinator::{
    run_workflow, MatchPlan, PartitioningChoice, Workflow, WorkflowConfig,
};
use pem::datagen::GeneratorConfig;
use pem::engine::backend::Threads;
use pem::matching::StrategyKind;
use pem::model::EntityId;
use pem::partition::{
    BlockingBased, PartitionStrategy, SizeBased, SortedNeighborhood,
};
use pem::util::proptest::forall;
use pem::util::GIB;

fn strategies() -> Vec<Box<dyn PartitionStrategy>> {
    vec![
        Box::new(SizeBased::with_max_size(120)),
        Box::new(SizeBased::auto()),
        Box::new(BlockingBased::product_type().with_bounds(150, 30)),
        Box::new(SortedNeighborhood::by_title(60).with_max_size(150)),
    ]
}

/// Property: same dataset + strategy + environment ⇒ byte-identical
/// serialized plans, and deserialization is lossless.
#[test]
fn prop_plan_built_twice_is_byte_identical() {
    forall("plan-determinism", 12, |rng| {
        let n = 100 + rng.gen_range(700);
        let seed = rng.gen_range(1 << 20) as u64;
        let data = GeneratorConfig::tiny()
            .with_entities(n)
            .with_seed(seed)
            .generate();
        let ce = ComputingEnv::new(
            1 + rng.gen_range(3),
            1 + rng.gen_range(4),
            GIB,
        );
        let kind = if rng.gen_bool(0.5) {
            StrategyKind::Wam
        } else {
            StrategyKind::Lrm
        };
        for strategy in strategies() {
            let a =
                MatchPlan::build(&data.dataset, strategy.as_ref(), kind, &ce)
                    .unwrap();
            let b =
                MatchPlan::build(&data.dataset, strategy.as_ref(), kind, &ce)
                    .unwrap();
            let bytes = a.to_bytes();
            assert_eq!(
                bytes,
                b.to_bytes(),
                "{} not deterministic (n={n}, seed={seed})",
                strategy.name()
            );
            // round trip through the serialized form is lossless
            let back = MatchPlan::from_bytes(&bytes).unwrap();
            assert_eq!(back.to_bytes(), bytes);
            assert_eq!(back.tasks, a.tasks);
            assert_eq!(back.task_mem, a.task_mem);
            assert!(back.matches_dataset(&data.dataset));
        }
    });
}

fn norm(result: &pem::model::MatchResult) -> Vec<(EntityId, EntityId, f32)> {
    let mut pairs: Vec<(EntityId, EntityId, f32)> =
        result.iter().map(|c| (c.e1, c.e2, c.sim)).collect();
    pairs.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    pairs
}

/// The legacy `WorkflowConfig` path and the new builder path produce
/// identical results (structure, comparisons, correspondences with
/// exact similarities) for both legacy strategies.
#[test]
fn builder_path_is_result_identical_to_legacy_config_path() {
    let data = GeneratorConfig::tiny()
        .with_entities(800)
        .with_seed(2010)
        .generate();
    let ce = ComputingEnv::new(1, 2, GIB);

    // §3.1 size-based
    let legacy_cfg = WorkflowConfig {
        partitioning: PartitioningChoice::SizeBased {
            max_size: Some(120),
        },
        ..WorkflowConfig::size_based(StrategyKind::Wam)
    }
    .with_engine(EngineChoice::Threads)
    .with_cache(8);
    let legacy = run_workflow(&data, &legacy_cfg, &ce).unwrap();
    let new = Workflow::for_dataset(&data.dataset)
        .strategy(SizeBased::with_max_size(120))
        .backend(Threads)
        .env(ce)
        .cache(8)
        .run()
        .unwrap();
    assert_eq!(new.n_partitions, legacy.n_partitions);
    assert_eq!(new.n_tasks, legacy.n_tasks);
    assert_eq!(new.metrics.comparisons, legacy.metrics.comparisons);
    assert_eq!(norm(&new.result), norm(&legacy.result));

    // §3.2 blocking-based
    let mut legacy_cfg = WorkflowConfig::blocking_based(StrategyKind::Wam)
        .with_engine(EngineChoice::Threads)
        .with_cache(8);
    if let PartitioningChoice::BlockingBased {
        max_size, min_size, ..
    } = &mut legacy_cfg.partitioning
    {
        *max_size = Some(150);
        *min_size = 30;
    }
    let legacy = run_workflow(&data, &legacy_cfg, &ce).unwrap();
    let new = Workflow::for_dataset(&data.dataset)
        .strategy(BlockingBased::product_type().with_bounds(150, 30))
        .backend(Threads)
        .env(ce)
        .cache(8)
        .run()
        .unwrap();
    assert_eq!(new.n_partitions, legacy.n_partitions);
    assert_eq!(new.n_misc_partitions, legacy.n_misc_partitions);
    assert_eq!(new.n_tasks, legacy.n_tasks);
    assert_eq!(new.metrics.comparisons, legacy.metrics.comparisons);
    assert_eq!(norm(&new.result), norm(&legacy.result));
}

/// `build_partitions` (the legacy pre-processing entry point) and the
/// plan built by the builder agree on the partition structure.
#[test]
fn legacy_build_partitions_agrees_with_plan() {
    let data = GeneratorConfig::tiny().with_entities(500).generate();
    let ce = ComputingEnv::new(1, 4, GIB);
    let cfg = WorkflowConfig::blocking_based(StrategyKind::Lrm);
    let parts =
        pem::coordinator::workflow::build_partitions(&data, &cfg, &ce)
            .unwrap();
    let planned = Workflow::for_dataset(&data.dataset)
        .matching(StrategyKind::Lrm)
        .strategy(BlockingBased::product_type())
        .env(ce)
        .plan()
        .unwrap();
    let plan = planned.plan();
    assert_eq!(plan.n_partitions(), parts.len());
    assert_eq!(plan.n_misc_partitions(), parts.n_misc());
    assert_eq!(plan.partitions.max_size(), parts.max_size());
    assert_eq!(plan.partitions.total_entities(), parts.total_entities());
}
