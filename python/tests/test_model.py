"""L2 strategy graphs: kernel path vs broadcast path, masks, params."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

WAM_P = jnp.asarray(model.WAM_DEFAULT_PARAMS, dtype=jnp.float32)
LRM_P = jnp.asarray(model.LRM_DEFAULT_PARAMS, dtype=jnp.float32)


def counts(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.poisson(1.5, size=shape).astype(np.float32))


def inputs(m, n, d, seed):
    return (
        counts((m, d), seed),
        counts((m, d), seed + 1),
        counts((n, d), seed + 2),
        counts((n, d), seed + 3),
    )


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 24),
    n=st.integers(2, 24),
    d=st.integers(4, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_wam_kernel_matches_broadcast(m, n, d, seed):
    at, ad, bt, bd = inputs(m, n, d, seed)
    # margin=1 keeps discard from zeroing, so full matrices compare
    p = jnp.asarray([0.5, 0.5, 0.75, 1.0], dtype=jnp.float32)
    k = model.wam(at, ad, bt, bd, p, use_kernel=True)
    r = model.wam(at, ad, bt, bd, p, use_kernel=False)
    np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 24),
    n=st.integers(2, 24),
    d=st.integers(4, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_lrm_kernel_matches_broadcast(m, n, d, seed):
    at, ad, bt, bd = inputs(m, n, d, seed)
    k = model.lrm(at, ad, bt, bd, LRM_P, use_kernel=True)
    r = model.lrm(at, ad, bt, bd, LRM_P, use_kernel=False)
    np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-5)


def test_wam_shapes_and_range():
    at, ad, bt, bd = inputs(16, 8, 32, 0)
    p = jnp.asarray([0.6, 0.4, 0.0, 0.0], dtype=jnp.float32)
    out = model.wam(at, ad, bt, bd, p)
    assert out.shape == (16, 8)
    o = np.asarray(out)
    assert o.min() >= 0.0 and o.max() <= 1.0 + 1e-6


def test_wam_threshold_discard():
    """Everything below threshold-margin must be exactly zero."""
    at, ad, bt, bd = inputs(16, 16, 32, 42)
    p = jnp.asarray([0.5, 0.5, 0.9, 0.1], dtype=jnp.float32)
    out = np.asarray(model.wam(at, ad, bt, bd, p))
    assert ((out == 0.0) | (out >= 0.8 - 1e-6)).all()


def test_wam_identical_partition_diagonal():
    at, ad, _, _ = inputs(12, 12, 48, 7)
    at, ad = at + 1.0, ad + 1.0  # non-empty rows
    p = jnp.asarray([0.5, 0.5, 0.75, 0.0], dtype=jnp.float32)
    out = np.asarray(model.wam(at, ad, at, ad, p))
    np.testing.assert_allclose(np.diag(out), 1.0, atol=1e-4)


def test_padding_rows_masked():
    at, ad, bt, bd = inputs(8, 8, 32, 3)
    # rows 5.. of A are padding (all-zero in both attributes)
    at = at.at[5:].set(0.0)
    ad = ad.at[5:].set(0.0)
    wam_out = np.asarray(
        model.wam(at, ad, bt, bd, jnp.asarray([0.5, 0.5, 0.0, 0.0]))
    )
    lrm_out = np.asarray(model.lrm(at, ad, bt, bd, LRM_P))
    assert (wam_out[5:] == 0.0).all()
    assert (lrm_out[5:] == 0.0).all()


def test_lrm_is_sigmoid_of_linear_combo():
    at, ad, bt, bd = inputs(6, 6, 32, 9)
    s_jac = np.asarray(ref.jaccard(at, bt))
    s_tri = np.asarray(ref.dice(ad, bd))
    dot = np.asarray(ref.pairwise_stats_ref(at, bt)[1]) + np.asarray(
        ref.pairwise_stats_ref(ad, bd)[1]
    )
    nsq_a = np.asarray(ref.row_normsq(at) + ref.row_normsq(ad))
    nsq_b = np.asarray(ref.row_normsq(bt) + ref.row_normsq(bd))
    s_cos = np.asarray(
        ref.cosine_from_stats(jnp.asarray(dot), jnp.asarray(nsq_a), jnp.asarray(nsq_b))
    )
    w0, w1, w2, w3 = map(float, LRM_P)
    expect = 1.0 / (1.0 + np.exp(-(w0 + w1 * s_jac + w2 * s_tri + w3 * s_cos)))
    got = np.asarray(model.lrm(at, ad, bt, bd, LRM_P))
    np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-5)


def test_lrm_monotone_in_similarity():
    """A pair identical in all attributes scores higher than a disjoint one."""
    d = 32
    x = jnp.zeros((2, d)).at[0, :8].set(2.0).at[1, 16:24].set(2.0)
    out = np.asarray(model.lrm(x, x, x, x, LRM_P))
    assert out[0, 0] > out[0, 1]
    assert out[1, 1] > out[1, 0]


def test_strategy_fn_dispatch():
    assert model.strategy_fn("wam") is model.wam
    assert model.strategy_fn("lrm") is model.lrm
    try:
        model.strategy_fn("nope")
        assert False
    except ValueError:
        pass
