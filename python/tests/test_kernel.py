"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, tile choices and value distributions; every
property asserts allclose against ``kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.similarity import (
    _pick_tile,
    mxu_utilization_estimate,
    pairwise_stats,
    vmem_footprint_bytes,
)

jax.config.update("jax_platform_name", "cpu")


def counts(shape, seed, scale=4.0):
    """Non-negative count-like features (hashed q-gram counts)."""
    rng = np.random.default_rng(seed)
    x = rng.poisson(lam=1.2, size=shape).astype(np.float32)
    return jnp.asarray(np.minimum(x, scale * 4))


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 48),
    n=st.integers(1, 48),
    d=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_stats_match_ref_random_shapes(m, n, d, seed):
    a = counts((m, d), seed)
    b = counts((n, d), seed + 1)
    minsum, dot = pairwise_stats(a, b)
    minsum_r, dot_r = ref.pairwise_stats_ref(a, b)
    np.testing.assert_allclose(minsum, minsum_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dot, dot_r, rtol=1e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    tile_m=st.sampled_from([1, 3, 8, 16, 32, 64]),
    tile_n=st.sampled_from([1, 3, 8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_stats_tile_invariance(tile_m, tile_n, seed):
    """Result must not depend on the tiling."""
    a = counts((24, 40), seed)
    b = counts((36, 40), seed + 7)
    minsum, dot = pairwise_stats(a, b, tile_m=tile_m, tile_n=tile_n)
    minsum_r, dot_r = ref.pairwise_stats_ref(a, b)
    np.testing.assert_allclose(minsum, minsum_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dot, dot_r, rtol=1e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_stats_float_values(seed):
    """Kernel is not count-specific: arbitrary non-negative floats."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0, 10, size=(16, 32)).astype(np.float32))
    b = jnp.asarray(rng.uniform(0, 10, size=(8, 32)).astype(np.float32))
    minsum, dot = pairwise_stats(a, b)
    minsum_r, dot_r = ref.pairwise_stats_ref(a, b)
    np.testing.assert_allclose(minsum, minsum_r, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(dot, dot_r, rtol=1e-5, atol=1e-3)


def test_stats_accepts_other_dtypes():
    a = jnp.ones((8, 16), dtype=jnp.bfloat16)
    b = jnp.ones((8, 16), dtype=jnp.int32)
    minsum, dot = pairwise_stats(a, b)
    assert minsum.dtype == jnp.float32 and dot.dtype == jnp.float32
    np.testing.assert_allclose(minsum, 16.0)
    np.testing.assert_allclose(dot, 16.0)


def test_stats_rejects_mismatched_d():
    with pytest.raises(ValueError):
        pairwise_stats(jnp.ones((4, 8)), jnp.ones((4, 9)))


def test_minsum_symmetry():
    a = counts((20, 32), 3)
    minsum_ab, dot_ab = pairwise_stats(a, a)
    np.testing.assert_allclose(minsum_ab, minsum_ab.T, atol=1e-5)
    np.testing.assert_allclose(dot_ab, dot_ab.T, atol=1e-4)
    # diagonal of minsum == row sums; diagonal of dot == squared norms
    np.testing.assert_allclose(
        jnp.diag(minsum_ab), ref.row_sums(a), rtol=1e-5
    )
    np.testing.assert_allclose(
        jnp.diag(dot_ab), ref.row_normsq(a), rtol=1e-5
    )


def test_zero_rows_give_zero_stats():
    a = jnp.zeros((4, 16))
    b = counts((6, 16), 11)
    minsum, dot = pairwise_stats(a, b)
    assert float(jnp.abs(minsum).max()) == 0.0
    assert float(jnp.abs(dot).max()) == 0.0


@given(d=st.integers(1, 512))
@settings(max_examples=30, deadline=None)
def test_pick_tile_divides(d):
    for pref in (1, 7, 16, 32, 600):
        t = _pick_tile(d, pref)
        assert 1 <= t <= max(pref, 1)
        assert d % t == 0


def test_similarity_ranges():
    """dice/jaccard/cosine all live in [0, 1] for non-negative inputs."""
    a = counts((16, 64), 5)
    b = counts((12, 64), 6)
    for fn in (ref.dice, ref.jaccard, ref.cosine):
        s = np.asarray(fn(a, b))
        assert s.min() >= -1e-6 and s.max() <= 1.0 + 1e-6


def test_jaccard_le_dice():
    """j = i/(x+y-i) <= 2i/(x+y) = dice, always."""
    a = counts((10, 32), 1)
    b = counts((14, 32), 2)
    j = np.asarray(ref.jaccard(a, b))
    d = np.asarray(ref.dice(a, b))
    assert (j <= d + 1e-6).all()


def test_identical_rows_score_one():
    a = counts((8, 32), 9) + 1.0  # ensure non-empty
    for fn in (ref.dice, ref.jaccard, ref.cosine):
        s = np.asarray(fn(a, a))
        np.testing.assert_allclose(np.diag(s), 1.0, atol=1e-4)


def test_vmem_footprint_monotone():
    assert vmem_footprint_bytes(32, 32, 256) < vmem_footprint_bytes(
        64, 64, 256
    )
    # default tile fits a 16 MiB VMEM with headroom
    assert vmem_footprint_bytes(32, 32, 256) < 4 * 2**20


def test_mxu_estimate_bounds():
    for tm, tn, d in [(8, 8, 64), (32, 32, 256), (128, 128, 128)]:
        u = mxu_utilization_estimate(tm, tn, d)
        assert 0.0 < u <= 1.0
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
