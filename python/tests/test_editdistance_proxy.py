"""Quantify the accelerated path's edit-distance substitution.

The WAM title matcher is Levenshtein similarity in the paper; the
accelerated path uses trigram Dice on hashed q-gram vectors (DESIGN.md
§Hardware-Adaptation).  These tests pin down that the proxy agrees with
true edit similarity on match *decisions* for realistic product titles —
the quantity EXPERIMENTS.md reports.
"""

import zlib

import numpy as np
import jax.numpy as jnp

from compile.kernels import ref

D = 256


def levenshtein(a: str, b: str) -> int:
    la, lb = len(a), len(b)
    prev = list(range(lb + 1))
    for i in range(1, la + 1):
        cur = [i] + [0] * lb
        for j in range(1, lb + 1):
            cost = 0 if a[i - 1] == b[j - 1] else 1
            cur[j] = min(prev[j] + 1, cur[j - 1] + 1, prev[j - 1] + cost)
        prev = cur
    return prev[lb]


def edit_sim(a: str, b: str) -> float:
    if not a and not b:
        return 1.0
    return 1.0 - levenshtein(a, b) / max(len(a), len(b))


def trigrams(s: str):
    s = f"##{s.lower()}##"
    return [s[i : i + 3] for i in range(len(s) - 2)]


def hashed_vec(s: str, d: int = D) -> np.ndarray:
    v = np.zeros(d, dtype=np.float32)
    for g in trigrams(s):
        v[zlib.crc32(g.encode()) % d] += 1.0
    return v


TITLES = [
    "Samsung SpinPoint F1 HD103UJ 1TB",
    "Samsung Spinpoint F1 HD103UJ 1 TB",      # near-dup of 0
    "Samsung SpinPoint F1 HD103UJ 1TB SATA",  # near-dup of 0
    "Western Digital Caviar Green WD10EADS",
    "WD Caviar Green WD10EADS 1TB",           # near-dup of 3
    "LG GH22NS50 DVD Burner black",
    "LG GH22NS50 DVD-Burner, black",          # near-dup of 5
    "Plextor PX-B320SA Blu-ray Combo",
    "TrekStor DataStation maxi m.u 1TB",
    "Intel X25-M G2 Postville 80GB SSD",
]
# pairs (i, j, is_match)
PAIRS = [
    (0, 1, True),
    (0, 2, True),
    (3, 4, True),
    (5, 6, True),
    (0, 3, False),
    (1, 4, False),
    (5, 7, False),
    (8, 9, False),
    (2, 9, False),
    (7, 8, False),
]


def test_proxy_decision_agreement():
    """Trigram-Dice and edit similarity agree on >= 90% of decisions."""
    thresh_edit, thresh_dice = 0.6, 0.6
    agree = 0
    for i, j, _ in PAIRS:
        e = edit_sim(TITLES[i].lower(), TITLES[j].lower())
        a = jnp.asarray(hashed_vec(TITLES[i])[None, :])
        b = jnp.asarray(hashed_vec(TITLES[j])[None, :])
        dice = float(ref.dice(a, b)[0, 0])
        agree += (e >= thresh_edit) == (dice >= thresh_dice)
    assert agree >= 9, f"only {agree}/10 decisions agree"


def test_proxy_separates_matches_from_nonmatches():
    dice_scores = {}
    for i, j, is_match in PAIRS:
        a = jnp.asarray(hashed_vec(TITLES[i])[None, :])
        b = jnp.asarray(hashed_vec(TITLES[j])[None, :])
        dice_scores[(i, j)] = (float(ref.dice(a, b)[0, 0]), is_match)
    match_min = min(s for s, m in dice_scores.values() if m)
    non_max = max(s for s, m in dice_scores.values() if not m)
    assert match_min > non_max, (match_min, non_max)


def test_proxy_correlates_with_edit_similarity():
    es, ds = [], []
    for i in range(len(TITLES)):
        for j in range(i + 1, len(TITLES)):
            es.append(edit_sim(TITLES[i].lower(), TITLES[j].lower()))
            a = jnp.asarray(hashed_vec(TITLES[i])[None, :])
            b = jnp.asarray(hashed_vec(TITLES[j])[None, :])
            ds.append(float(ref.dice(a, b)[0, 0]))
    r = np.corrcoef(es, ds)[0, 1]
    assert r > 0.8, f"correlation {r}"
