"""AOT lowering smoke tests: HLO text is produced and looks loadable."""

import os
import subprocess
import sys

import pytest

from compile import aot, model


@pytest.mark.parametrize("strategy", model.STRATEGIES)
def test_lower_variant_produces_hlo_text(strategy):
    text = aot.lower_variant(strategy, m=64, d=32)
    assert "HloModule" in text
    assert "ENTRY" in text
    # 5 parameters: 4 matrices + the params vector
    assert text.count("parameter(") >= 5
    # must be plain HLO, not a Mosaic custom call (interpret=True)
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_artifact_name_roundtrip():
    assert aot.artifact_name("wam", 512) == "wam_m512_d256.hlo.txt"
    assert aot.artifact_name("lrm", 128, 64) == "lrm_m128_d64.hlo.txt"


def test_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--capacities",
            "32",
            "--feature-dim",
            "16",
        ],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    # header + one line per (strategy x capacity)
    assert len(manifest) == 1 + len(model.STRATEGIES)
    for line in manifest[1:]:
        name, strategy, m, d, n_params = line.split()
        assert (out / name).exists()
        assert strategy in model.STRATEGIES
        assert (int(m), int(d), int(n_params)) == (32, 16, model.N_PARAMS)
