"""Layer-1 Pallas kernel: tiled all-pairs similarity statistics.

The compute hot spot of a match task is evaluating a match strategy over
the cross product of two entity partitions.  Entities are embedded (on the
Rust side, `pem::features`) as hashed q-gram count vectors, so a partition
is a dense ``f32[M, D]`` matrix.  Every matcher the paper's two strategies
use (TriGram/Dice, Jaccard, Cosine, and the q-gram proxy for edit
distance) is a function of exactly two pairwise statistics:

  ``minsum[i, j] = sum_k min(a[i, k], b[j, k])``   (multiset intersection)
  ``dot[i, j]    = sum_k a[i, k] * b[j, k]``       (inner product)

together with per-row aggregates (``sum_k a[i, k]``, ``||a[i]||``) that are
O(M·D) and computed outside the kernel.  Note ``sum_k max(a,b) =
sum(a) + sum(b) - minsum``, so Jaccard needs no third matrix.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the kernel tiles the
``[M, D] x [N, D] -> [M, N]`` computation over a 2-D grid of
``(TILE_M, TILE_N)`` output blocks.  Per grid step only two input strips
(``TILE_M x D`` and ``TILE_N x D``) live in VMEM; ``dot`` hits the MXU via
``jnp.dot`` and ``minsum`` is a VPU broadcast-min reduction.  HBM traffic
per output tile row is O(M·D) instead of the O(M²·D) a naive broadcast
would materialize.

Pallas runs with ``interpret=True`` everywhere in this repo: the CPU PJRT
client cannot execute Mosaic custom calls.  The BlockSpecs are still the
real TPU schedule and are what §Perf estimates VMEM/MXU numbers from.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  (32, 32) keeps the broadcast-min intermediate at
# 32*32*D floats (1 MiB at D=256) — comfortably inside a 16 MiB VMEM
# budget together with the two input strips.  See EXPERIMENTS.md §Perf for
# the sweep over candidates.
DEFAULT_TILE_M = 32
DEFAULT_TILE_N = 32


def _pick_tile(dim: int, preferred: int) -> int:
    """Largest tile <= preferred that divides dim (dim >= 1)."""
    t = min(preferred, dim)
    while dim % t != 0:
        t -= 1
    return t


def _stats_kernel(a_ref, b_ref, minsum_ref, dot_ref):
    """One (TILE_M, TILE_N) output block of minsum / dot."""
    a = a_ref[...]  # [TILE_M, D]
    b = b_ref[...]  # [TILE_N, D]
    # Multiset intersection: broadcast-min then reduce over the feature
    # axis.  VPU work, no MXU.
    minsum_ref[...] = jnp.sum(
        jnp.minimum(a[:, None, :], b[None, :, :]), axis=-1
    )
    # Inner products: MXU matmul on TPU.
    dot_ref[...] = jnp.dot(a, b.T, preferred_element_type=jnp.float32)


def pairwise_stats(
    a: jax.Array,
    b: jax.Array,
    *,
    tile_m: int = DEFAULT_TILE_M,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = True,
):
    """All-pairs (minsum, dot) between rows of ``a`` and rows of ``b``.

    Args:
      a: ``f32[M, D]`` feature matrix (rows = entities of partition A).
      b: ``f32[N, D]`` feature matrix.
      tile_m / tile_n: preferred output-tile shape; shrunk to divide M/N.
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      ``(minsum, dot)``, both ``f32[M, N]``.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[1]:
        raise ValueError(f"bad shapes {a.shape} x {b.shape}")
    m, d = a.shape
    n, _ = b.shape
    tm = _pick_tile(m, tile_m)
    tn = _pick_tile(n, tile_n)
    grid = (m // tm, n // tn)
    out_shape = [
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((m, n), jnp.float32),
    ]
    kernel = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    minsum, dot = kernel(a32, b32)
    return minsum, dot


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n"))
def pairwise_stats_jit(a, b, tile_m=DEFAULT_TILE_M, tile_n=DEFAULT_TILE_N):
    return pairwise_stats(a, b, tile_m=tile_m, tile_n=tile_n)


def vmem_footprint_bytes(tile_m: int, tile_n: int, d: int) -> int:
    """Estimated peak VMEM bytes for one grid step (f32).

    Two input strips + broadcast-min intermediate + two output tiles.
    Used by the §Perf BlockSpec sweep; mirrored by the Rust-side estimate
    in ``pem::runtime::vmem``.
    """
    strips = (tile_m + tile_n) * d
    broadcast = tile_m * tile_n * d
    outs = 2 * tile_m * tile_n
    return 4 * (strips + broadcast + outs)


def mxu_utilization_estimate(tile_m: int, tile_n: int, d: int) -> float:
    """Fraction of a 128x128 MXU the dot tile keeps busy (structural)."""
    eff_m = min(tile_m, 128) / 128.0
    eff_n = min(tile_n, 128) / 128.0
    eff_k = min(d, 128) / 128.0
    return eff_m * eff_n * eff_k
