"""Pure-jnp oracle for the Pallas similarity kernel and the matchers.

Everything here is the straightforward O(M·N·D) broadcast formulation —
the CORE correctness reference the kernel and the Rust matchers are tested
against.  No Pallas, no tiling tricks.
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-9


def pairwise_stats_ref(a, b):
    """(minsum, dot) between all row pairs — naive broadcast version."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    minsum = jnp.sum(jnp.minimum(a[:, None, :], b[None, :, :]), axis=-1)
    dot = a @ b.T
    return minsum, dot


def row_sums(x):
    return jnp.sum(x.astype(jnp.float32), axis=-1)


def row_normsq(x):
    x = x.astype(jnp.float32)
    return jnp.sum(x * x, axis=-1)


def dice_from_stats(minsum, sum_a, sum_b):
    """TriGram (Dice) similarity: 2·|A∩B| / (|A| + |B|)."""
    denom = sum_a[:, None] + sum_b[None, :]
    return jnp.where(denom > 0, 2.0 * minsum / (denom + EPS), 0.0)


def jaccard_from_stats(minsum, sum_a, sum_b):
    """Jaccard similarity: |A∩B| / |A∪B|, with |A∪B| = |A|+|B|-|A∩B|."""
    union = sum_a[:, None] + sum_b[None, :] - minsum
    return jnp.where(union > 0, minsum / (union + EPS), 0.0)


def cosine_from_stats(dot, normsq_a, normsq_b):
    """Cosine similarity from inner products and squared norms."""
    denom = jnp.sqrt(normsq_a)[:, None] * jnp.sqrt(normsq_b)[None, :]
    return jnp.where(denom > 0, dot / (denom + EPS), 0.0)


def dice(a, b):
    minsum, _ = pairwise_stats_ref(a, b)
    return dice_from_stats(minsum, row_sums(a), row_sums(b))


def jaccard(a, b):
    minsum, _ = pairwise_stats_ref(a, b)
    return jaccard_from_stats(minsum, row_sums(a), row_sums(b))


def cosine(a, b):
    _, dot = pairwise_stats_ref(a, b)
    return cosine_from_stats(dot, row_normsq(a), row_normsq(b))
