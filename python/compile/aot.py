"""AOT lowering: jax match-strategy graphs → HLO text artifacts.

Emits one HLO module per (strategy × partition-capacity) variant into
``artifacts/``, plus a ``manifest.txt`` the Rust runtime uses to discover
them.  HLO **text** (not ``.serialize()``) is the interchange format: jax
>= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Module signature (all f32):
    (a_title[M,D], a_desc[M,D], b_title[M,D], b_desc[M,D], params[4])
        -> (combined[M,M],)           # lowered with return_tuple=True

Run:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Partition capacities to compile.  The Rust coordinator pads every
# partition to the smallest capacity that fits (powers of two keep the
# Pallas grid regular).  1024 covers the paper's largest partition size
# (Fig 6 sweeps up to 1000).
CAPACITIES = (128, 256, 512, 1024)
FEATURE_DIM = 256

MANIFEST_NAME = "manifest.txt"


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(strategy: str, m: int, d: int = FEATURE_DIM) -> str:
    """Lower one (strategy, capacity) variant and return its HLO text."""
    mat = jax.ShapeDtypeStruct((m, d), jnp.float32)
    par = jax.ShapeDtypeStruct((model.N_PARAMS,), jnp.float32)

    def fn(a_title, a_desc, b_title, b_desc, params):
        return (
            model.match_task(
                strategy, a_title, a_desc, b_title, b_desc, params
            ),
        )

    lowered = jax.jit(fn).lower(mat, mat, mat, mat, par)
    return to_hlo_text(lowered)


def artifact_name(strategy: str, m: int, d: int = FEATURE_DIM) -> str:
    return f"{strategy}_m{m}_d{d}.hlo.txt"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--capacities", type=int, nargs="*", default=list(CAPACITIES)
    )
    ap.add_argument("--feature-dim", type=int, default=FEATURE_DIM)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_lines = [
        "# pem artifact manifest: name strategy capacity feature_dim n_params"
    ]
    for strategy in model.STRATEGIES:
        for m in args.capacities:
            name = artifact_name(strategy, m, args.feature_dim)
            text = lower_variant(strategy, m, args.feature_dim)
            path = os.path.join(args.out_dir, name)
            with open(path, "w") as f:
                f.write(text)
            manifest_lines.append(
                f"{name} {strategy} {m} {args.feature_dim} {model.N_PARAMS}"
            )
            print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, MANIFEST_NAME), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(args.out_dir, MANIFEST_NAME)}")


if __name__ == "__main__":
    main()
