"""Layer-2 JAX graphs for the paper's two match strategies.

A *match task* compares two entity partitions A and B.  On the Rust side
each partition arrives as two hashed-trigram feature matrices (title and
description, ``f32[M, D]``; rows of zeros pad partitions smaller than M).
The strategy graph returns the combined ``f32[M, M]`` similarity matrix;
padded pairs are forced to 0 so the Rust side can extract correspondences
as simply "entries >= threshold".

Strategies (paper §5.1):

* **WAM** — two matchers, weighted average:
    - title matcher: the paper uses edit distance; the accelerated path
      substitutes trigram Dice similarity on the title (q-gram distance is
      the standard bound/proxy for edit distance — the exact Levenshtein
      matcher lives in ``pem::matching`` and python/tests quantify the
      agreement).
    - description matcher: TriGram (Dice) similarity.
    - combined = w1·s_title + w2·s_desc, then the *threshold-discard*
      optimization: entries that cannot reach the decision threshold are
      zeroed (this is the paper's memory optimization — only candidate
      correspondences survive).

* **LRM** — three matchers, logistic-regression combination:
    - Jaccard on title, TriGram (Dice) on description, Cosine on the
      concatenated (title ‖ description) vector.  The cosine of the
      concatenation is computed from the two per-attribute kernel calls:
      dot = dot_t + dot_d and ||x||² = ||x_t||² + ||x_d||².
    - combined = sigmoid(w0 + w1·jac + w2·tri + w3·cos).

Both graphs call the Layer-1 Pallas kernel once per attribute, so the
whole strategy lowers into a single HLO module with exactly two kernel
instantiations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.similarity import pairwise_stats

STRATEGIES = ("wam", "lrm")

# Number of runtime parameters each strategy takes (a flat f32 vector so
# the Rust coordinator can retune weights without recompiling):
#   wam: [w_title, w_desc, decision_threshold, discard_margin]
#   lrm: [w0 (bias), w_jaccard, w_trigram, w_cosine]
N_PARAMS = 4

# Defaults used by tests and by the Rust side unless overridden.
WAM_DEFAULT_PARAMS = (0.5, 0.5, 0.75, 0.0)
LRM_DEFAULT_PARAMS = (-8.0, 4.0, 5.0, 6.0)


def _pair_mask(sum_a, sum_b):
    """1.0 where both rows are real entities (non-empty), else 0.0."""
    return (sum_a[:, None] > 0) & (sum_b[None, :] > 0)


def wam(a_title, a_desc, b_title, b_desc, params, *, use_kernel=True):
    """Weighted-average matcher strategy.  Returns f32[M, N] combined sim."""
    stats = pairwise_stats if use_kernel else ref.pairwise_stats_ref
    sum_at, sum_bt = ref.row_sums(a_title), ref.row_sums(b_title)
    sum_ad, sum_bd = ref.row_sums(a_desc), ref.row_sums(b_desc)

    minsum_t, _ = stats(a_title, b_title)
    minsum_d, _ = stats(a_desc, b_desc)

    s_title = ref.dice_from_stats(minsum_t, sum_at, sum_bt)
    s_desc = ref.dice_from_stats(minsum_d, sum_ad, sum_bd)

    w1, w2, threshold, margin = params[0], params[1], params[2], params[3]
    combined = (w1 * s_title + w2 * s_desc) / (w1 + w2)
    mask = _pair_mask(sum_at + sum_ad, sum_bt + sum_bd)
    combined = jnp.where(mask, combined, 0.0)
    # Threshold-discard: drop every pair that already misses the decision
    # threshold (minus a safety margin).  This is what keeps WAM's memory
    # per pair at ~"candidates only" (paper §5.1).
    return jnp.where(combined >= threshold - margin, combined, 0.0)


def lrm(a_title, a_desc, b_title, b_desc, params, *, use_kernel=True):
    """Logistic-regression matcher strategy.  Returns f32[M, N] score."""
    stats = pairwise_stats if use_kernel else ref.pairwise_stats_ref
    sum_at, sum_bt = ref.row_sums(a_title), ref.row_sums(b_title)
    sum_ad, sum_bd = ref.row_sums(a_desc), ref.row_sums(b_desc)
    nsq_at, nsq_bt = ref.row_normsq(a_title), ref.row_normsq(b_title)
    nsq_ad, nsq_bd = ref.row_normsq(a_desc), ref.row_normsq(b_desc)

    minsum_t, dot_t = stats(a_title, b_title)
    minsum_d, dot_d = stats(a_desc, b_desc)

    s_jac = ref.jaccard_from_stats(minsum_t, sum_at, sum_bt)
    s_tri = ref.dice_from_stats(minsum_d, sum_ad, sum_bd)
    # Cosine over the concatenated title‖desc vector, assembled from the
    # per-attribute stats (no third kernel call needed).
    s_cos = ref.cosine_from_stats(
        dot_t + dot_d, nsq_at + nsq_ad, nsq_bt + nsq_bd
    )

    w0, w1, w2, w3 = params[0], params[1], params[2], params[3]
    z = w0 + w1 * s_jac + w2 * s_tri + w3 * s_cos
    score = jax.nn.sigmoid(z)
    mask = _pair_mask(sum_at + sum_ad, sum_bt + sum_bd)
    return jnp.where(mask, score, 0.0)


def strategy_fn(name: str):
    if name == "wam":
        return wam
    if name == "lrm":
        return lrm
    raise ValueError(f"unknown strategy {name!r} (want one of {STRATEGIES})")


def match_task(name: str, a_title, a_desc, b_title, b_desc, params,
               *, use_kernel=True):
    """Uniform entry point: one match task = one strategy evaluation."""
    return strategy_fn(name)(
        a_title, a_desc, b_title, b_desc, params, use_kernel=use_kernel
    )
