//! Quickstart: generate a small product-offer dataset, run the paper's
//! blocking-based match workflow, and inspect the result.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pem::cluster::ComputingEnv;
use pem::coordinator::workflow::EngineChoice;
use pem::coordinator::{run_workflow, WorkflowConfig};
use pem::datagen::GeneratorConfig;
use pem::matching::StrategyKind;
use pem::util::GIB;

fn main() -> anyhow::Result<()> {
    // 1. A dataset with known injected duplicates (offers of the same
    //    product from different shops, corrupted titles/descriptions).
    let data = GeneratorConfig::tiny().with_entities(2_000).generate();
    println!(
        "dataset: {} offers, {} products, {} true duplicate pairs",
        data.dataset.len(),
        data.n_products,
        data.truth.len()
    );

    // 2. The paper's workflow: blocking by product type → partition
    //    tuning → match task generation → parallel matching (WAM).
    //    Threads engine = really match, on this machine.
    let cfg = WorkflowConfig::blocking_based(StrategyKind::Wam)
        .with_engine(EngineChoice::Threads)
        .with_cache(16);
    let ce = ComputingEnv::new(1, 4, 3 * GIB);
    let out = run_workflow(&data, &cfg, &ce)?;

    // 3. Inspect.
    println!(
        "partitions: {} ({} misc), match tasks: {}",
        out.n_partitions, out.n_misc_partitions, out.n_tasks
    );
    println!("metrics: {}", out.metrics.summary());
    let q = out.result.quality(&data.truth);
    println!(
        "quality vs injected truth: precision={:.3} recall={:.3} f1={:.3}",
        q.precision, q.recall, q.f1
    );
    println!("wall-clock: {:?}", out.elapsed);

    // 4. A few example correspondences.
    let mut sample: Vec<_> = out.result.iter().collect();
    sample.sort_by(|a, b| b.sim.partial_cmp(&a.sim).unwrap());
    let schema = &data.dataset.schema;
    for c in sample.iter().take(3) {
        let (e1, e2) = (
            data.dataset.get(c.e1).unwrap(),
            data.dataset.get(c.e2).unwrap(),
        );
        println!(
            "  {:.2}  {:?} <-> {:?}",
            c.sim,
            e1.title(schema),
            e2.title(schema)
        );
    }
    Ok(())
}
