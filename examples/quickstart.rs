//! Quickstart: generate a small product-offer dataset, plan the
//! paper's blocking-based match workflow, inspect the plan, execute it.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pem::cluster::ComputingEnv;
use pem::coordinator::Workflow;
use pem::engine::backend::Threads;
use pem::partition::BlockingBased;
use pem::util::GIB;

fn main() -> anyhow::Result<()> {
    // 1. A dataset with known injected duplicates (offers of the same
    //    product from different shops, corrupted titles/descriptions).
    let data = pem::datagen::GeneratorConfig::tiny()
        .with_entities(2_000)
        .generate();
    println!(
        "dataset: {} offers, {} products, {} true duplicate pairs",
        data.dataset.len(),
        data.n_products,
        data.truth.len()
    );

    // 2. Plan the paper's workflow: blocking by product type →
    //    partition tuning → match task generation.  `.plan()` is the
    //    cheap half — inspect partitions and task skew before paying
    //    for execution (`pem plan` is the CLI form of this step).
    let planned = Workflow::for_dataset(&data.dataset)
        .strategy(BlockingBased::product_type())
        .backend(Threads) // really match, on this machine
        .env(ComputingEnv::new(1, 4, 3 * GIB))
        .cache(16)
        .plan()?;
    println!("\n{}\n", planned.plan().summary());

    // 3. Execute the plan and inspect the merged result.
    let out = planned.execute()?;
    println!("metrics: {}", out.metrics.summary());
    let q = out.result.quality(&data.truth);
    println!(
        "quality vs injected truth: precision={:.3} recall={:.3} f1={:.3}",
        q.precision, q.recall, q.f1
    );
    println!("wall-clock: {:?}", out.elapsed);

    // 4. A few example correspondences.
    let mut sample: Vec<_> = out.result.iter().collect();
    sample.sort_by(|a, b| b.sim.partial_cmp(&a.sim).unwrap());
    let schema = &data.dataset.schema;
    for c in sample.iter().take(3) {
        let (e1, e2) = (
            data.dataset.get(c.e1).unwrap(),
            data.dataset.get(c.e2).unwrap(),
        );
        println!(
            "  {:.2}  {:?} <-> {:?}",
            c.sim,
            e1.title(schema),
            e2.title(schema)
        );
    }
    Ok(())
}
