//! Elasticity & fault tolerance (paper §4): match services can be added
//! on demand, removed, and the workflow survives node failures by
//! reassigning the failed service's tasks.
//!
//! Demonstrated on the virtual-time simulator: the same workload runs
//! (a) healthy, (b) with a node lost mid-run, (c) on a heterogeneous
//! cluster with a half-speed straggler — the pull-based scheduler
//! load-balances all three.
//!
//! ```bash
//! cargo run --release --example elastic_cluster
//! ```

use pem::cluster::{ComputingEnv, HeterogeneousEnv, NodeSpec};
use pem::coordinator::MatchPlan;
use pem::datagen::GeneratorConfig;
use pem::engine::sim::{run_heterogeneous, SimConfig};
use pem::engine::{calibrate, sim};
use pem::matching::StrategyKind;
use pem::partition::BlockingBased;
use pem::store::DataService;
use pem::util::{fmt_nanos, GIB};

fn main() -> anyhow::Result<()> {
    let data = GeneratorConfig::default().with_entities(6_000).generate();
    let kind = StrategyKind::Wam;
    let ce = ComputingEnv::new(4, 4, 3 * GIB);
    // one plan, three executions below — the plan/execute split at the
    // engine level
    let plan = MatchPlan::build(
        &data.dataset,
        &BlockingBased::product_type().with_bounds(250, 50),
        kind,
        &ce,
    )?;
    let parts = &plan.partitions;
    let tasks = plan.tasks.clone();
    let store = DataService::build(&data.dataset, parts);
    let cost =
        calibrate::calibrated_params(&data.dataset, kind, 100, 7);
    println!(
        "workload: {} partitions, {} tasks, calibrated {:.0} ns/pair\n",
        parts.len(),
        tasks.len(),
        cost.pair_ns
    );

    // (a) healthy 4-node run
    let mut cfg = SimConfig::new(kind, cost);
    cfg.cache_capacity = 16;
    let healthy = sim::run(&ce, parts, tasks.clone(), &store, cfg);
    println!(
        "(a) healthy 4-node cluster:        {}",
        fmt_nanos(healthy.metrics.makespan_ns)
    );

    // (b) node 3 dies a quarter of the way in — tasks are reassigned
    let mut cfg = SimConfig::new(kind, cost);
    cfg.cache_capacity = 16;
    cfg.failures = vec![(healthy.metrics.makespan_ns / 4, 3)];
    let failed = sim::run(&ce, parts, tasks.clone(), &store, cfg);
    println!(
        "(b) node 3 fails at 25%:           {}  (all {} tasks still completed)",
        fmt_nanos(failed.metrics.makespan_ns),
        failed.metrics.tasks
    );

    // (c) heterogeneous: 3 full-speed nodes + 1 straggler at half speed
    let mut env = HeterogeneousEnv::uniform(&ce);
    env.nodes[3] = NodeSpec {
        speed: 0.5,
        ..env.nodes[3]
    };
    let mut cfg = SimConfig::new(kind, cost);
    cfg.cache_capacity = 16;
    let hetero =
        run_heterogeneous(&env, parts, tasks, &store, &mut cfg);
    println!(
        "(c) heterogeneous (one 0.5x node): {}  (imbalance {:.2})",
        fmt_nanos(hetero.metrics.makespan_ns),
        hetero.metrics.imbalance()
    );
    println!(
        "\npull-based scheduling keeps the straggler fed with fewer tasks \
         instead of stalling the makespan (paper §4)."
    );
    Ok(())
}
