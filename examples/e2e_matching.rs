//! End-to-end driver: the full system on a real (small) workload.
//!
//! Exercises every layer in composition:
//!
//! 1. generate the small match problem (20k offers by default; scaled
//!    with `--entities`);
//! 2. train the LRM combiner on a labeled sample (logistic regression);
//! 3. run blocking → partition tuning → task generation → **real**
//!    parallel matching on the thread engine (1 node, this host);
//! 4. re-run the same workflow on the simulated paper testbed
//!    (4 nodes × 4 cores, partition caches, affinity scheduling) and
//!    report the headline metric: execution time vs 1 core, i.e. the
//!    paper's speedup claim;
//! 5. report match quality against the injected ground truth.
//!
//! ```bash
//! cargo run --release --example e2e_matching -- --entities 20000
//! ```
//! Recorded in EXPERIMENTS.md §End-to-end.

use pem::cluster::ComputingEnv;
use pem::coordinator::Workflow;
use pem::datagen::GeneratorConfig;
use pem::engine::backend::{Sim, SimOptions, Threads};
use pem::matching::train::{train_lrm, training_pairs, TrainConfig};
use pem::matching::{MatchStrategy, StrategyKind};
use pem::metrics::speedups;
use pem::partition::BlockingBased;
use pem::util::cli::Args;
use pem::util::{fmt_nanos, GIB};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let n = args.get_or("entities", 20_000usize)?;
    let seed = args.get_or("seed", 2010u64)?;

    println!("=== pem end-to-end driver ===\n");
    let t0 = std::time::Instant::now();
    let data = GeneratorConfig::default()
        .with_entities(n)
        .with_seed(seed)
        .generate();
    println!(
        "[1] dataset: {} offers / {} products / {} duplicate pairs ({:?})",
        data.dataset.len(),
        data.n_products,
        data.truth.len(),
        t0.elapsed()
    );

    // [2] train the learner-based strategy on a labeled sample
    let t1 = std::time::Instant::now();
    let pairs = training_pairs(&data, 400, 3, seed ^ 0xbeef);
    let params = train_lrm(
        &pairs,
        &TrainConfig {
            init: Some(pem::matching::StrategyParams::lrm_default().values),
            learning_rate: 0.1,
            ..TrainConfig::default()
        },
    );
    println!(
        "[2] trained LRM on {} labeled pairs → weights {:?} ({:?})",
        pairs.len(),
        params.values,
        t1.elapsed()
    );

    // [3] real parallel matching on this host (thread engine)
    let lrm = MatchStrategy::new(StrategyKind::Lrm).with_params(params);
    for (name, strategy) in [
        ("WAM", MatchStrategy::new(StrategyKind::Wam)),
        ("LRM(trained)", lrm),
    ] {
        let ce = ComputingEnv::new(1, 4, 3 * GIB);
        let out = Workflow::for_dataset(&data.dataset)
            .match_strategy(strategy)
            .strategy(BlockingBased::product_type())
            .backend(Threads)
            .env(ce)
            .cache(16)
            .run()?;
        let q = out.result.quality(&data.truth);
        println!(
            "[3] {name}: {} partitions ({} misc), {} tasks, {} comparisons",
            out.n_partitions,
            out.n_misc_partitions,
            out.n_tasks,
            out.metrics.comparisons
        );
        println!(
            "    matched {} pairs: precision={:.3} recall={:.3} f1={:.3}  hr={:.0}%  wall={:?}",
            out.result.len(),
            q.precision,
            q.recall,
            q.f1,
            out.metrics.hit_ratio() * 100.0,
            out.elapsed
        );
    }

    // [4] headline: scale-out on the simulated paper testbed
    println!("\n[4] scale-out on the simulated paper testbed (CE=(4,4,3GB), c=16):");
    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        let mut times = Vec::new();
        print!("    {}: ", kind.name());
        for cores in [1usize, 4, 16] {
            let nodes = cores.div_ceil(4).max(1);
            let ce = ComputingEnv::new(nodes, cores.div_ceil(nodes), 3 * GIB);
            let out = Workflow::for_dataset(&data.dataset)
                .matching(kind)
                .strategy(BlockingBased::product_type())
                .backend(Sim(SimOptions::default()))
                .env(ce)
                .cache(16)
                .run()?;
            times.push(out.metrics.makespan_ns);
            print!(
                "{}@{}c  ",
                fmt_nanos(out.metrics.makespan_ns),
                cores
            );
        }
        let s = speedups(&times);
        println!("→ speedup {:.1}x @16 cores", s[2]);
    }

    println!("\ntotal driver wall-clock: {:?}", t0.elapsed());
    Ok(())
}
