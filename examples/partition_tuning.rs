//! The paper's Figure 3 walkthrough: blocking + partition tuning on a
//! 3,600-product Drives & Storage subset.
//!
//! Reproduces the worked example exactly: blocks of 1300/700/400/200/
//! 200/200 + a 600-entity misc block, max partition size 700, minimum
//! 210 → 6 partitions and **12** match tasks (a size-based partitioning
//! of the same input yields 6 partitions but **21** tasks).
//!
//! ```bash
//! cargo run --release --example partition_tuning
//! ```

use pem::blocking::Blocks;
use pem::model::EntityId;
use pem::partition::{
    generate_tasks, partition_size_based, tune, PartitionKind, TuningConfig,
};

fn main() {
    // Figure 3 (left): product-type blocks of the Drives & Storage subset
    let spec: &[(&str, usize)] = &[
        ("3.5-drive", 1300),
        ("2.5-drive", 700),
        ("DVD-RW", 400),
        ("Blu-ray", 200),
        ("HD-DVD", 200),
        ("CD-RW", 200),
    ];
    let misc_size = 600;

    let mut blocks = Blocks::new();
    let mut next = 0u32;
    for (key, n) in spec {
        for _ in 0..*n {
            blocks.add(key, EntityId(next));
            next += 1;
        }
    }
    for _ in 0..misc_size {
        blocks.add_misc(EntityId(next));
        next += 1;
    }
    println!("input: {} products in {} blocks + {} misc", next, spec.len(), misc_size);
    for (key, n) in spec {
        println!("  block {key:<10} {n}");
    }

    // partition tuning with the paper's bounds
    let cfg = TuningConfig::new(700, 210);
    let parts = tune(&blocks, cfg);
    println!("\npartition tuning (max=700, min=210) → {} partitions:", parts.len());
    for p in parts.iter() {
        let kind = match &p.kind {
            PartitionKind::Block { key } => format!("block {key}"),
            PartitionKind::SubBlock { key, index, count } => {
                format!("split {key} [{}/{}]", index + 1, count)
            }
            PartitionKind::Aggregate { keys } => {
                format!("aggregate {{{}}}", keys.join(", "))
            }
            PartitionKind::Misc { index, count } => {
                format!("misc [{}/{}]", index + 1, count)
            }
            PartitionKind::SizeBased => "size-based".into(),
            PartitionKind::Window { index, count } => {
                format!("window [{}/{}]", index + 1, count)
            }
        };
        println!("  {}  {:<34} {} entities", p.id, kind, p.len());
    }

    // Figure 3 (right): match task generation
    let tasks = generate_tasks(&parts);
    println!("\nmatch tasks ({}):", tasks.len());
    for t in &tasks {
        if t.left == t.right {
            println!("  T{:<2} {} × itself", t.id, t.left);
        } else {
            println!("  T{:<2} {} × {}", t.id, t.left, t.right);
        }
    }
    assert_eq!(tasks.len(), 12, "paper's Figure 3 reports 12 match tasks");

    // comparison: size-based partitioning of the same 3,600 products
    let ids: Vec<EntityId> = (0..next).map(EntityId).collect();
    let sb = partition_size_based(&ids, 600);
    let sb_tasks = generate_tasks(&sb);
    println!(
        "\nsize-based comparison: {} partitions → {} match tasks (paper: 21)",
        sb.len(),
        sb_tasks.len()
    );
    assert_eq!(sb_tasks.len(), 21);
    println!("\nFigure 3 reproduced: 12 tasks (blocking-based) vs 21 (size-based).");
}
