//! The distributed runtime end-to-end: the paper's §4 workflow / data /
//! match services as real TCP endpoints on localhost.
//!
//! Everything the simulator models — task assignment RMI, partition
//! fetches, completion reports with piggybacked cache status,
//! heartbeats — happens here over actual sockets through the
//! length-prefixed binary wire protocol (`pem::rpc`), driven by the
//! third execution engine (`pem::engine::dist`).
//!
//! ```bash
//! cargo run --release --example distributed_match
//! ```
//!
//! The same services also run as separate processes (or hosts):
//!
//! ```bash
//! pem serve --entities 20000 --workflow-port 7401 --data-port 7402
//! pem distmatch --workflow 127.0.0.1:7401 --data 127.0.0.1:7402 --threads 4
//! ```

use pem::cluster::ComputingEnv;
use pem::coordinator::Workflow;
use pem::datagen::GeneratorConfig;
use pem::engine::backend::{Dist, DistOptions, Threads};
use pem::partition::BlockingBased;
use pem::util::{fmt_bytes, fmt_nanos, GIB};

fn main() -> anyhow::Result<()> {
    let data = GeneratorConfig::small().with_seed(2010).generate();
    println!(
        "dataset: {} product offers, {} known duplicate pairs",
        data.dataset.len(),
        data.truth.len()
    );

    // 3 match-service nodes × 2 worker threads, partition caches of 8,
    // affinity scheduling — all talking over localhost TCP.  The plan
    // is built first (inspectable), then handed to the Dist backend.
    let ce = ComputingEnv::new(3, 2, GIB);
    let planned = Workflow::for_dataset(&data.dataset)
        .strategy(BlockingBased::product_type())
        .backend(Dist(DistOptions::default()))
        .env(ce)
        .cache(8)
        .plan()?;
    println!("\n{}", planned.plan().summary());
    let out = planned.execute()?;

    println!(
        "\nblocking-based workflow over TCP: {} partitions ({} misc) → {} tasks",
        out.n_partitions, out.n_misc_partitions, out.n_tasks
    );
    println!(
        "completed in {} on {} nodes × {} threads",
        fmt_nanos(out.metrics.makespan_ns),
        ce.nodes,
        ce.threads_per_node
    );
    println!(
        "comparisons: {}   matches: {}",
        out.metrics.comparisons,
        out.result.len()
    );
    println!(
        "data plane:  {} actually shipped over sockets ({} partition fetches \
         served, cache hit ratio {:.0}%)",
        fmt_bytes(out.metrics.bytes_fetched),
        out.metrics.cache_misses,
        out.metrics.hit_ratio() * 100.0
    );
    println!(
        "control plane: {} messages, {} affinity-preferred assignments",
        out.metrics.control_messages, out.metrics.affinity_hits
    );

    let q = out.result.quality(&data.truth);
    println!(
        "\nquality: precision={:.3} recall={:.3} f1={:.3}",
        q.precision, q.recall, q.f1
    );

    // cross-check against the in-process thread engine on the same seed:
    // the wire round trip is lossless, so the results must be identical
    let t = Workflow::for_dataset(&data.dataset)
        .strategy(BlockingBased::product_type())
        .backend(Threads)
        .env(ce)
        .cache(8)
        .run()?;
    assert_eq!(t.result.len(), out.result.len());
    println!(
        "thread-engine cross-check: identical {} correspondences ✓",
        t.result.len()
    );
    Ok(())
}
