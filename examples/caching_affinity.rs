//! Partition caching + affinity-based scheduling (paper §4 / §5.4).
//!
//! Runs the same blocking-based workflow on the simulated paper testbed
//! with caching disabled, caching+FIFO, and caching+affinity, and prints
//! the Table 1-style comparison (t_nc, t_c, Δ, Δ/t_nc, hr).
//!
//! ```bash
//! cargo run --release --example caching_affinity
//! ```

use pem::cluster::ComputingEnv;
use pem::coordinator::{Policy, Workflow};
use pem::datagen::GeneratorConfig;
use pem::engine::backend::{Sim, SimOptions};
use pem::matching::StrategyKind;
use pem::partition::BlockingBased;
use pem::util::stats::Table;
use pem::util::GIB;

fn main() -> anyhow::Result<()> {
    let data = GeneratorConfig::default().with_entities(8_000).generate();
    let kind = StrategyKind::Wam;
    // the same simulated run with caching disabled, caching+FIFO, and
    // caching+affinity
    let cell = |ce: ComputingEnv, cache: usize, policy: Policy| {
        Workflow::for_dataset(&data.dataset)
            .matching(kind)
            .strategy(
                BlockingBased::product_type().with_bounds(200, 40),
            )
            .backend(Sim(SimOptions::default()))
            .env(ce)
            .cache(cache)
            .policy(policy)
            .run()
    };

    println!("caching & affinity on the simulated testbed (c = 16)\n");
    let mut table =
        Table::new(vec!["cores", "t_nc", "t_c(fifo)", "t_c(affinity)", "Δ/t_nc", "hr"]);
    for cores in [1usize, 4, 8, 16] {
        let nodes = cores.div_ceil(4).max(1);
        let ce = ComputingEnv::new(nodes, cores.div_ceil(nodes), 3 * GIB);

        let nc = cell(ce, 0, Policy::Affinity)?;
        let fifo = cell(ce, 16, Policy::Fifo)?;
        let aff = cell(ce, 16, Policy::Affinity)?;

        let t_nc = nc.metrics.makespan_ns as f64;
        let t_c = aff.metrics.makespan_ns as f64;
        table.row(vec![
            format!("{cores}"),
            pem::util::fmt_nanos(nc.metrics.makespan_ns),
            pem::util::fmt_nanos(fifo.metrics.makespan_ns),
            pem::util::fmt_nanos(aff.metrics.makespan_ns),
            format!("{:.0}%", 100.0 * (t_nc - t_c) / t_nc),
            format!("{:.0}%", 100.0 * aff.metrics.hit_ratio()),
        ]);
    }
    println!("{}", table.render());
    println!(
        "expected shape (paper Tables 1-2): caching improves ~10-26%, \
         hit ratios ~76-83%, biggest effect at 1 core."
    );
    Ok(())
}
