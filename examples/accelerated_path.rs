//! The accelerated match path: AOT-compiled XLA executables (Layer 2
//! strategy graphs over the Layer 1 Pallas similarity kernel) driven
//! from Rust via PJRT — Python is not involved at match time.
//!
//! Requires `make artifacts` to have been run once.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example accelerated_path
//! ```

use pem::datagen::GeneratorConfig;
use pem::matching::{MatchStrategy, StrategyKind};
use pem::model::EntityId;
use pem::partition::{partition_size_based, PartitionId};
use pem::runtime::{default_artifact_dir, vmem, MatchEngine, PjrtExecutor};
use pem::store::DataService;
use pem::worker::{RustExecutor, TaskExecutor};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    let engine = Arc::new(MatchEngine::new(&dir).map_err(|e| {
        anyhow::anyhow!("{e:#}\nhint: run `make artifacts` first")
    })?);
    println!("artifacts from {}:", dir.display());
    for e in &engine.manifest().entries {
        println!(
            "  {:<26} {} capacity={} dim={}",
            e.name,
            e.strategy.name(),
            e.capacity,
            e.feature_dim
        );
    }

    // a small workload: two partitions of 100 offers
    let data = GeneratorConfig::tiny().with_entities(200).generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, 100);
    let store = DataService::build(&data.dataset, &parts);
    let left = store.fetch(PartitionId(0));

    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        let strategy = MatchStrategy::new(kind);
        let pjrt = PjrtExecutor::new(engine.clone(), strategy);
        let rust = RustExecutor::new(strategy);

        // intra-partition task: the generator's duplicates are
        // id-adjacent, so matching a partition with itself finds them
        let t = std::time::Instant::now();
        let accel = pjrt.execute(&left, &left, true);
        let t_accel = t.elapsed();
        let t = std::time::Instant::now();
        let exact = rust.execute(&left, &left, true);
        let t_exact = t.elapsed();

        let set = |cs: &[pem::model::Correspondence]| {
            cs.iter()
                .map(|c| c.pair())
                .collect::<std::collections::HashSet<_>>()
        };
        let (sa, se) = (set(&accel), set(&exact));
        let agree = sa.intersection(&se).count();
        println!(
            "\n{}: pjrt {} matches in {:?}; rust {} matches in {:?}; \
             agreement {}/{}",
            kind.name(),
            sa.len(),
            t_accel,
            se.len(),
            t_exact,
            agree,
            sa.union(&se).count().max(1)
        );
    }

    // the kernel's TPU schedule estimates (§Perf)
    println!("\nPallas kernel BlockSpec estimates (f32, D=256):");
    println!("tile     VMEM        MXU-util  fits-16MiB");
    for (tm, tn) in [(16, 16), (32, 32), (64, 64), (128, 128)] {
        let e = vmem::estimate(tm, tn, 256);
        println!(
            "{tm:>3}x{tn:<3}  {:>10}  {:>7.3}  {}",
            pem::util::fmt_bytes(e.vmem_bytes),
            e.mxu_utilization,
            e.fits_vmem_16mib
        );
    }
    Ok(())
}
