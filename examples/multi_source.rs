//! Matching multiple input sources (paper §3.3).
//!
//! Two duplicate-free shop catalogs are matched against each other,
//! three ways:
//!
//! * **union** — combine both sources and run the standard
//!   single-source workflow (finds intra- and cross-source duplicates);
//! * **duplicate-free Cartesian** — `m·n` cross-source tasks instead of
//!   `(m+n)(m+n−1)/2`;
//! * **duplicate-free blocked** — the same blocking on both sources with
//!   *paired* partition tuning, matching corresponding blocks only
//!   (misc partitions of either side × all partitions of the other).
//!
//! ```bash
//! cargo run --release --example multi_source
//! ```

use pem::blocking::BlockingMethod;
use pem::cluster::ComputingEnv;
use pem::coordinator::multi_source::{
    cross_quality, run_two_source_workflow, split_duplicate_free,
    union_sources, TwoSourceMode,
};
use pem::coordinator::Workflow;
use pem::datagen::GeneratorConfig;
use pem::engine::backend::Threads;
use pem::matching::{MatchStrategy, StrategyKind};
use pem::partition::SizeBased;
use pem::util::GIB;

fn main() -> anyhow::Result<()> {
    // one generated world, split into two duplicate-free shop catalogs
    let data = GeneratorConfig::tiny().with_entities(2_000).generate();
    let (a, b, cross_truth) =
        split_duplicate_free(&data.dataset, &data.truth);
    println!(
        "source A: {} offers, source B: {} offers, {} cross-source duplicate pairs",
        a.len(),
        b.len(),
        cross_truth.len()
    );
    let ce = ComputingEnv::new(1, 4, 3 * GIB);
    let strategy = MatchStrategy::new(StrategyKind::Wam);

    // ——— union approach ———
    let union = union_sources(vec![a.clone(), b.clone()]);
    let u = Workflow::for_dataset(&union)
        .strategy(SizeBased::with_max_size(200))
        .backend(Threads)
        .env(ce)
        .run()?;
    println!(
        "\nunion:                  {} tasks, {} comparisons, {} matches",
        u.n_tasks,
        u.metrics.comparisons,
        u.result.len()
    );

    // ——— duplicate-free cartesian ———
    let cart = run_two_source_workflow(
        &a,
        &b,
        &TwoSourceMode::Cartesian {
            max_size: Some(200),
        },
        strategy,
        &ce,
    )?;
    let qc = cross_quality(&cart.result, &cross_truth, a.len() as u32);
    println!(
        "duplicate-free m·n:     {} tasks (union equivalent {}), {} comparisons, recall {:.3}",
        cart.n_tasks, cart.union_equivalent_tasks, cart.comparisons, qc.recall
    );

    // ——— duplicate-free with paired-tuned blocking ———
    let blocked = run_two_source_workflow(
        &a,
        &b,
        &TwoSourceMode::Blocked {
            method: BlockingMethod::product_type(),
            max_size: Some(200),
            min_size: 40,
        },
        strategy,
        &ce,
    )?;
    let qb = cross_quality(&blocked.result, &cross_truth, a.len() as u32);
    println!(
        "duplicate-free blocked: {} tasks, {} comparisons ({}% of m·n), recall {:.3}",
        blocked.n_tasks,
        blocked.comparisons,
        100 * blocked.comparisons / cart.comparisons.max(1),
        qb.recall
    );
    println!(
        "\nblocking prunes the cross-source search space while paired \
         partition tuning keeps corresponding blocks aligned (§3.3)."
    );
    Ok(())
}
